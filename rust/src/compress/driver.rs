//! The recursive compression driver (Fig. 1 of the paper).
//!
//! Whenever a layer's uncompressed tail reaches `2L` (+ sink handling), the
//! oldest `L` tail rows form the *partition* and the next `L` rows the *lag
//! reference*; the policy scores the partition per head, top `floor(r*L)`
//! survive, and the cache is compacted.  The same code path runs after
//! prefill ingestion and after every decode append, which is what makes the
//! scheme "recursive in both prefill and decode stages".
//!
//! Sink rows (`S`) are never scored or evicted; the last partition and the
//! modulo remainder form the sliding window and stay whole — together this
//! realizes Eq. 10 exactly (asserted by integration tests against
//! kvcache::ratio).

use anyhow::Result;

use crate::config::CompressionConfig;
use crate::config::PolicyKind;
use crate::kvcache::KvCache;

use super::policy::{PartitionInput, Scorer};
use super::topk;

/// Record of one partition compression (telemetry / tests).
#[derive(Debug, Clone, PartialEq)]
pub struct CompressionEvent {
    pub layer: usize,
    /// First row of the compressed window (absolute row index).
    pub start: usize,
    /// Window length (= lag L).
    pub l: usize,
    /// Rows kept per head.
    pub kept: usize,
    /// Per-layer cache lengths captured immediately after this event —
    /// the snapshot the serving `Event::Compression` line carries, so a
    /// streamed Eq. 10 trajectory stays per-event exact even when several
    /// events fire in one driver pass.
    pub layer_lens: Vec<usize>,
}

/// Run as many compression rounds as are due on every eligible layer.
/// Returns the events performed (empty when nothing was due).
pub fn maybe_compress(
    cache: &mut KvCache,
    cfg: &CompressionConfig,
    scorer: &mut dyn Scorer,
) -> Result<Vec<CompressionEvent>> {
    let mut events = Vec::new();
    if cfg.policy == PolicyKind::None {
        return Ok(events);
    }
    let keep = cfg.keep_per_partition();
    if keep >= cfg.lag {
        return Ok(events); // r == 1: nothing to evict
    }
    for layer in 0..cache.n_layers {
        if layer < cfg.skip_layers {
            continue;
        }
        loop {
            let len = cache.len(layer);
            let boundary = cache.layers[layer].boundary;
            // The first compression on a layer must also leave the sink
            // prefix untouched: the window starts after max(boundary, S).
            let start = boundary.max(cfg.sink);
            if len < start + 2 * cfg.lag {
                break;
            }
            let ev = if scorer.global_scope() {
                // Global scope scores the whole evictable region, which may
                // reach behind the paged (frozen) prefix; bring the layer
                // back to contiguous storage first.  No-op unless an
                // earlier turn ran a partition-scope policy on this cache —
                // pure global-scope caches never freeze past the sink.
                cache.thaw_layer(layer);
                compress_global(cache, cfg, scorer, layer, start, keep)?
            } else {
                compress_one(cache, cfg, scorer, layer, start, keep)?
            };
            events.push(ev);
        }
    }
    Ok(events)
}

fn compress_one(
    cache: &mut KvCache,
    cfg: &CompressionConfig,
    scorer: &mut dyn Scorer,
    layer: usize,
    start: usize,
    keep: usize,
) -> Result<CompressionEvent> {
    let l = cfg.lag;
    let d = cache.d_head;
    let n_heads = cache.n_heads;
    let mut keeps: Vec<Vec<usize>> = Vec::with_capacity(n_heads);
    let mut scratch = Vec::new();
    for head in 0..n_heads {
        let cur = cache.window(layer, head, start, l);
        let lag = cache.window(layer, head, start + l, l);
        let inp = PartitionInput {
            layer,
            head,
            k_cur: cur.k,
            v_cur: cur.v,
            k_ref: lag.k,
            v_ref: lag.v,
            attn_acc: cur.attn,
            positions: cur.pos,
            l,
            d,
        };
        let scores = scorer.score(&inp)?;
        debug_assert_eq!(scores.len(), l);
        let mut kept_idx = Vec::with_capacity(keep);
        topk::topk_indices_into(&scores, keep, &mut scratch, &mut kept_idx);
        keeps.push(kept_idx);
    }
    cache.compact_layer(layer, start, l, &keeps)?;
    Ok(CompressionEvent { layer, start, l, kept: keep, layer_lens: cache.lens() })
}

/// Global-scope eviction (original H2O): evict `L - keep` rows per event
/// from the whole region between the sink and the newest `L` window, by
/// lowest score.  Same eviction budget and trigger cadence as the partition
/// path, so the retained-length law (Eq. 10) is unchanged.
fn compress_global(
    cache: &mut KvCache,
    cfg: &CompressionConfig,
    scorer: &mut dyn Scorer,
    layer: usize,
    trigger_start: usize,
    keep: usize,
) -> Result<CompressionEvent> {
    let len = cache.len(layer);
    let d = cache.d_head;
    let start = cfg.sink.min(len);
    let window_len = len - cfg.lag - start; // evictable region length
    let evict = cfg.lag - keep;
    debug_assert!(window_len >= evict);
    let n_heads = cache.n_heads;
    let mut keeps: Vec<Vec<usize>> = Vec::with_capacity(n_heads);
    let mut scratch = Vec::new();
    for head in 0..n_heads {
        let cur = cache.window(layer, head, start, window_len);
        let inp = PartitionInput {
            layer,
            head,
            k_cur: cur.k,
            v_cur: cur.v,
            // no lag reference in global scope; score policies that need
            // one are partition-scoped by construction
            k_ref: &[],
            v_ref: &[],
            attn_acc: cur.attn,
            positions: cur.pos,
            l: window_len,
            d,
        };
        let scores = scorer.score(&inp)?;
        debug_assert_eq!(scores.len(), window_len);
        let mut kept_idx = Vec::with_capacity(window_len - evict);
        topk::topk_indices_into(&scores, window_len - evict, &mut scratch, &mut kept_idx);
        keeps.push(kept_idx);
    }
    cache.compact_layer(layer, start, window_len, &keeps)?;
    // In global scope `boundary` is purely a cadence counter: advancing it
    // exactly like the partition path (trigger start + keep) makes events
    // fire at the same lengths, so Eq. 10 holds for every policy and the
    // comparisons stay apples-to-apples.
    cache.layers[layer].boundary = trigger_start + keep;
    Ok(CompressionEvent {
        layer,
        start,
        l: window_len,
        kept: window_len - evict,
        layer_lens: cache.lens(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::policy::make_policy;
    use crate::kvcache::ratio;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn mk_cfg(sink: usize, lag: usize, ratio: f64, policy: PolicyKind) -> CompressionConfig {
        CompressionConfig { policy, sink, lag, ratio, ..Default::default() }
    }

    fn fill(cache: &mut KvCache, n: usize, seed: u64) {
        let mut rng = Rng::seed_from(seed);
        let w = cache.n_layers * cache.n_heads * cache.d_head;
        for _ in 0..n {
            let t = cache.appended as i32;
            let k: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
            let v: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
            cache.append_token(&k, &v, t).unwrap();
        }
    }

    #[test]
    fn matches_eq10_exactly() {
        // Stream tokens one by one; after every append run the driver; the
        // retained length must equal the paper's closed form at every step.
        let cfg = mk_cfg(4, 16, 0.5, PolicyKind::LagKv);
        let mut scorer = make_policy(cfg.policy, 0);
        let mut cache = KvCache::new(2, 2, 4);
        for ls in 1..=300usize {
            fill(&mut cache, 1, ls as u64);
            maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap();
            let want = ratio::retained_len(ls, cfg.sink, cfg.lag, cfg.keep_per_partition());
            assert_eq!(cache.len(0), want, "at Ls={ls}");
            assert_eq!(cache.len(1), want, "at Ls={ls}");
        }
    }

    #[test]
    fn events_carry_per_event_length_snapshots() {
        let cfg = mk_cfg(2, 8, 0.5, PolicyKind::LagKv);
        let mut scorer = make_policy(cfg.policy, 0);
        let mut cache = KvCache::new(2, 1, 2);
        fill(&mut cache, 120, 9);
        let events = maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap();
        assert!(events.len() >= 2, "bulk compression fires several events");
        // lengths only shrink across a pass, per layer
        for pair in events.windows(2) {
            let (a, b) = (&pair[0], &pair[1]);
            assert!(a.layer_lens.iter().zip(&b.layer_lens).all(|(x, y)| y <= x));
        }
        // the last snapshot is the final state; earlier ones are NOT just
        // copies of it (each event captured its own moment)
        assert_eq!(events.last().unwrap().layer_lens, cache.lens());
        assert_ne!(events.first().unwrap().layer_lens, cache.lens());
    }

    #[test]
    fn sink_rows_never_evicted() {
        let cfg = mk_cfg(4, 8, 0.25, PolicyKind::LagKv);
        let mut scorer = make_policy(cfg.policy, 0);
        let mut cache = KvCache::new(1, 2, 4);
        fill(&mut cache, 200, 7);
        maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap();
        for h in 0..2 {
            let pos = cache.positions(0, h);
            assert_eq!(&pos[..4], &[0, 1, 2, 3], "sink must survive (head {h})");
        }
    }

    #[test]
    fn window_tail_stays_whole() {
        // After compression, the last rows must be the most recent tokens,
        // contiguous (the sliding window of Fig. 1).
        let cfg = mk_cfg(4, 16, 0.5, PolicyKind::LagKv);
        let mut scorer = make_policy(cfg.policy, 0);
        let mut cache = KvCache::new(1, 1, 4);
        let n = 4 + 16 * 4 + 5; // partitions=4, rem=5
        fill(&mut cache, n, 11);
        maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap();
        let pos = cache.positions(0, 0);
        let tail = cfg.lag + 5; // L + mod
        let want: Vec<i32> = ((n - tail) as i32..n as i32).collect();
        assert_eq!(&pos[pos.len() - tail..], &want[..]);
    }

    #[test]
    fn skip_layers_exempt() {
        let mut cfg = mk_cfg(4, 8, 0.5, PolicyKind::L2Norm);
        cfg.skip_layers = 2;
        let mut scorer = make_policy(cfg.policy, 0);
        let mut cache = KvCache::new(3, 1, 4);
        fill(&mut cache, 100, 3);
        maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap();
        assert_eq!(cache.len(0), 100);
        assert_eq!(cache.len(1), 100);
        assert!(cache.len(2) < 100);
    }

    #[test]
    fn policy_none_is_identity() {
        let cfg = mk_cfg(4, 8, 0.5, PolicyKind::None);
        let mut scorer = make_policy(cfg.policy, 0);
        let mut cache = KvCache::new(1, 1, 2);
        fill(&mut cache, 64, 5);
        let ev = maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap();
        assert!(ev.is_empty());
        assert_eq!(cache.len(0), 64);
    }

    #[test]
    fn ratio_one_is_identity() {
        let cfg = mk_cfg(4, 8, 1.0, PolicyKind::LagKv);
        let mut scorer = make_policy(cfg.policy, 0);
        let mut cache = KvCache::new(1, 1, 2);
        fill(&mut cache, 64, 5);
        let ev = maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap();
        assert!(ev.is_empty());
    }

    #[test]
    fn batch_ingest_equals_streaming_appends() {
        // Prefill-then-compress must land in the same state as append-one-
        // at-a-time-with-compression (recursion is order-insensitive here
        // because scores depend only on chunk contents).
        let cfg = mk_cfg(2, 8, 0.5, PolicyKind::LagKv);
        let n = 100;
        let mk = |stream: bool| {
            let mut scorer = make_policy(cfg.policy, 0);
            let mut cache = KvCache::new(1, 2, 4);
            let mut rng = Rng::seed_from(99);
            let w = cache.n_layers * cache.n_heads * cache.d_head;
            let rows: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
                .map(|_| {
                    (
                        (0..w).map(|_| rng.normal()).collect(),
                        (0..w).map(|_| rng.normal()).collect(),
                    )
                })
                .collect();
            for (t, (k, v)) in rows.iter().enumerate() {
                cache.append_token(k, v, t as i32).unwrap();
                if stream {
                    maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap();
                }
            }
            if !stream {
                maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap();
            }
            cache
        };
        let a = mk(true);
        let b = mk(false);
        assert_eq!(a.positions(0, 0), b.positions(0, 0));
        assert_eq!(a.positions(0, 1), b.positions(0, 1));
        assert_eq!(a.head_k(0, 0), b.head_k(0, 0));
    }

    #[test]
    fn prop_invariants_all_policies() {
        prop::check(40, |g| {
            let kinds = PolicyKind::all();
            let kind = *g.pick(kinds);
            let sink = g.usize(0, 6);
            let lag = g.usize(2, 24);
            let ratio = [0.5, 0.25, 0.167, 0.125][g.usize(0, 3)];
            let n = g.usize(1, 200);
            let cfg = mk_cfg(sink, lag, ratio, kind);
            let mut scorer = make_policy(kind, g.case as u64);
            let mut cache = KvCache::new(2, 2, 3);
            fill(&mut cache, n, g.case as u64 + 1);
            maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap();
            for layer in 0..2 {
                // length law
                let want = if kind == PolicyKind::None {
                    n
                } else {
                    crate::kvcache::ratio::retained_len(
                        n,
                        sink,
                        lag,
                        cfg.keep_per_partition(),
                    )
                };
                if cache.len(layer) != want {
                    return Err(format!(
                        "{}: len {} != {} (n={n} S={sink} L={lag} r={ratio})",
                        kind.name(),
                        cache.len(layer),
                        want
                    ));
                }
                for head in 0..2 {
                    let pos = cache.positions(layer, head);
                    // positions strictly ascending (temporal order kept)
                    if pos.windows(2).any(|w| w[0] >= w[1]) {
                        return Err(format!("{}: positions not ascending", kind.name()));
                    }
                    // sink prefix intact
                    let s = sink.min(n).min(pos.len());
                    for i in 0..s {
                        if pos[i] != i as i32 {
                            return Err(format!("{}: sink evicted", kind.name()));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}

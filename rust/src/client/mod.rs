//! Typed blocking client SDK for the `v1` wire protocol.
//!
//! Every caller that talks to a lagkv server — `serve_demo`, the CI smoke
//! binary, the TCP e2e tests, `lagkv ops` — goes through this module; no
//! caller hand-rolls JSON.  The SDK is a thin blocking veneer over
//! [`crate::api`]: requests are typed structs serialized by their own
//! `to_json`, replies are parsed back into the coordinator's typed shapes.
//!
//! ```no_run
//! use lagkv::client::{Client, StreamItem};
//! use lagkv::coordinator::GenerateParams;
//!
//! let mut client = Client::connect(7199).unwrap();
//! // one-shot: folded Response
//! let resp = client.generate(None, GenerateParams::new("the pass key <a>")).unwrap();
//! println!("{}", resp.text);
//! // streaming: typed events, cancellable mid-decode
//! let mut stream = client.generate_stream(7, GenerateParams::new("...")).unwrap();
//! while let Some(item) = stream.next().unwrap() {
//!     if let StreamItem::Event(ev) = item {
//!         println!("{ev:?}");
//!     }
//! }
//! // ops: the control plane
//! let stats = client.stats().unwrap();
//! let drained = client.drain().unwrap();
//! println!("{} models, draining={}", stats.models.len(), drained.draining);
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use crate::api::{
    self, CancelAck, CancelRequest, CheckpointRequest, CheckpointResponse, DrainRequest,
    DrainResponse, GenerateRequest, InfoRequest, InfoResponse, SessionsRequest,
    SessionsResponse, StatsRequest, StatsResponse, TraceRequest, TraceResponse,
    UndrainRequest, UndrainResponse,
};
use crate::coordinator::{ApiError, Event, GenerateParams, Response};
use crate::util::json::Json;

/// A blocking connection to one lagkv server.
///
/// One request/stream at a time per connection: while a
/// [`GenStream`] is live, drive it to its terminal event (its borrow of
/// the client enforces this) before issuing the next call.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// One line read off a live stream: a typed [`Event`], or the ack of a
/// cancel issued on this connection (acks interleave with events).
#[derive(Debug, Clone, PartialEq)]
pub enum StreamItem {
    Event(Event),
    CancelAck(CancelAck),
}

impl Client {
    pub fn connect(port: u16) -> Result<Client> {
        let stream = TcpStream::connect(("127.0.0.1", port))
            .with_context(|| format!("connecting to 127.0.0.1:{port}"))?;
        let writer = stream.try_clone().context("cloning client stream")?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Write one raw line.  Escape hatch for protocol tests (malformed
    /// lines, the legacy compat shim); SDK methods never go through this.
    pub fn send_raw(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    fn send_json(&mut self, v: &Json) -> Result<()> {
        self.send_raw(&v.to_string())
    }

    /// Read one JSON line (blocking).  A closed connection is an error.
    pub fn read_json(&mut self) -> Result<Json> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            bail!("server closed the connection");
        }
        Json::parse(&line)
    }

    /// Raw request/one-reply exchange.  Escape hatch for protocol tests
    /// (e.g. asserting the legacy compat shim end-to-end).
    pub fn raw_call(&mut self, line: &str) -> Result<Json> {
        self.send_raw(line)?;
        self.read_json()
    }

    /// One-shot generation: returns the folded [`Response`] (its `error`
    /// field carries any typed rejection — queue-full, draining, ...).
    pub fn generate(&mut self, id: Option<u64>, params: GenerateParams) -> Result<Response> {
        let req = GenerateRequest { id, stream: false, params };
        self.send_json(&req.to_json())?;
        let v = self.read_json()?;
        parse_oneshot(&v)
    }

    /// Streaming generation: returns a handle yielding typed [`Event`]s
    /// until the terminal `Done`/`Error` (a rejected submit yields one
    /// terminal `Error` event).
    pub fn generate_stream(&mut self, id: u64, params: GenerateParams) -> Result<GenStream<'_>> {
        let req = GenerateRequest { id: Some(id), stream: true, params };
        self.send_json(&req.to_json())?;
        Ok(GenStream { client: self, done: false, id, pending_acks: 0 })
    }

    /// Cancel a request by id (possibly one submitted on another
    /// connection).  Returns whether the id was live.  Only valid while no
    /// stream is in flight here — mid-stream, use [`GenStream::cancel`].
    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        self.send_json(&CancelRequest { id }.to_json())?;
        let v = self.read_json()?;
        Ok(CancelAck::from_json(&v)?.found)
    }

    /// Control plane: every model's pool/prefix/coordinator/queue gauges.
    pub fn stats(&mut self) -> Result<StatsResponse> {
        let v = self.op_call(&StatsRequest.to_json())?;
        StatsResponse::from_json(&v)
    }

    /// Control plane: deployment facts (models, buckets, policies, caps).
    pub fn info(&mut self) -> Result<InfoResponse> {
        let v = self.op_call(&InfoRequest.to_json())?;
        InfoResponse::from_json(&v)
    }

    /// Control plane: list stored sessions (all models, or one).
    pub fn sessions(&mut self, model: Option<&str>) -> Result<SessionsResponse> {
        let req = SessionsRequest { model: model.map(str::to_string), delete: None };
        let v = self.op_call(&req.to_json())?;
        SessionsResponse::from_json(&v)
    }

    /// Control plane: drop a stored session by id.  Returns how many
    /// entries were deleted (across models, unless one is named).
    pub fn delete_session(&mut self, model: Option<&str>, id: &str) -> Result<u64> {
        let req = SessionsRequest {
            model: model.map(str::to_string),
            delete: Some(id.to_string()),
        };
        let v = self.op_call(&req.to_json())?;
        Ok(SessionsResponse::from_json(&v)?.deleted)
    }

    /// Control plane: close admission (typed `draining` rejections from
    /// here on) while in-flight work finishes.  Reversible with
    /// [`Client::undrain`].
    pub fn drain(&mut self) -> Result<DrainResponse> {
        let v = self.op_call(&DrainRequest.to_json())?;
        DrainResponse::from_json(&v)
    }

    /// Control plane: reopen admission after a drain (the rollback half of
    /// a rolling restart).
    pub fn undrain(&mut self) -> Result<UndrainResponse> {
        let v = self.op_call(&UndrainRequest.to_json())?;
        UndrainResponse::from_json(&v)
    }

    /// Control plane: flush every model's disk store (journal the live
    /// session/prefix inventory, fsync, compact the WAL).  Empty when the
    /// server runs without `--store-dir`.
    pub fn checkpoint(&mut self) -> Result<CheckpointResponse> {
        let v = self.op_call(&CheckpointRequest.to_json())?;
        CheckpointResponse::from_json(&v)
    }

    /// Control plane: recent request spans and latency histogram
    /// summaries per model (the telemetry ring's live snapshot).
    pub fn trace(&mut self) -> Result<TraceResponse> {
        let v = self.op_call(&TraceRequest.to_json())?;
        TraceResponse::from_json(&v)
    }

    /// Send a control-plane op and read its reply, surfacing a server-side
    /// rejection (`{"error": ...}` line) as a typed failure.
    fn op_call(&mut self, req: &Json) -> Result<Json> {
        self.send_json(req)?;
        let v = self.read_json()?;
        if v.opt("op").is_none() {
            if let Some(e) = v.opt("error") {
                bail!("server rejected the op: {}", ApiError::from_json(e)?);
            }
        }
        Ok(v)
    }
}

/// Parse a one-shot reply line: the full response shape, or the server's
/// bare `{"error": ...}` rejection of an unparseable line.
fn parse_oneshot(v: &Json) -> Result<Response> {
    if v.opt("id").is_none() {
        if let Some(e) = v.opt("error") {
            return Ok(Response::from_error(0, ApiError::from_json(e)?));
        }
    }
    api::response_from_json(v)
}

/// A live NDJSON event stream.  Borrows the client exclusively until the
/// terminal event, so request/reply framing can never interleave.
pub struct GenStream<'a> {
    client: &'a mut Client,
    done: bool,
    id: u64,
    /// Cancels sent whose acks have not been read yet.  The ack and the
    /// terminal `cancelled` event race on the server's writer lock, so the
    /// terminal path drains outstanding acks — a stale ack left in the
    /// socket would corrupt the next call's framing.
    pending_acks: usize,
}

impl GenStream<'_> {
    /// The request id this stream was submitted under.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Ask the server to abort this generation; the stream then terminates
    /// with a `cancelled` error event (plus an interleaved
    /// [`StreamItem::CancelAck`]).
    pub fn cancel(&mut self) -> Result<()> {
        self.pending_acks += 1;
        self.client.send_json(&CancelRequest { id: self.id }.to_json())
    }

    /// Read acks still owed after the terminal event, so the connection is
    /// left exactly line-aligned for the next call.
    fn drain_acks(&mut self) -> Result<()> {
        while self.pending_acks > 0 {
            let v = self.client.read_json()?;
            CancelAck::from_json(&v).context("draining post-terminal cancel acks")?;
            self.pending_acks -= 1;
        }
        Ok(())
    }

    /// Next line: `None` after the terminal event.
    pub fn next(&mut self) -> Result<Option<StreamItem>> {
        if self.done {
            return Ok(None);
        }
        let v = self.client.read_json()?;
        match v.opt("event").and_then(|e| e.as_str().ok()) {
            Some("cancel_ack") => {
                self.pending_acks = self.pending_acks.saturating_sub(1);
                Ok(Some(StreamItem::CancelAck(CancelAck::from_json(&v)?)))
            }
            Some(_) => {
                let ev = api::event_from_json(&v)?;
                if ev.is_terminal() {
                    self.done = true;
                    self.drain_acks()?;
                }
                Ok(Some(StreamItem::Event(ev)))
            }
            None => {
                // A rejected submit answers with a one-shot response line
                // (typed error); a malformed line with {"error": ...}.
                // Either way the stream is over — surface it as the
                // terminal error event.
                self.done = true;
                self.drain_acks()?;
                let resp = parse_oneshot(&v)?;
                let error = resp.error.unwrap_or_else(|| ApiError::EngineFailure {
                    message: "stream reply carried no event and no error".to_string(),
                });
                Ok(Some(StreamItem::Event(Event::Error { id: resp.id, error })))
            }
        }
    }

    /// Drain the stream and fold its events into a [`Response`]
    /// (stream/one-shot parity is pinned by tests on this path).
    pub fn wait(mut self) -> Result<Response> {
        let mut events = Vec::new();
        while let Some(item) = self.next()? {
            if let StreamItem::Event(ev) = item {
                events.push(ev);
            }
        }
        Ok(Response::from_events(events))
    }
}

//! Cross-layer property and regression tests (hermetic — no artifacts).
//!
//! Taxonomy (see ROADMAP "Open items"):
//! * **property** — Eq. 10 ledger reconciliation, sink immunity, per-head
//!   shape contract, top-k tie/NaN behavior, stream/one-shot parity of the
//!   serving API, tier churn against a real disk store (per-tier ledger
//!   exactness + bit-identical spill→fault round trips), quantized-block
//!   codec properties (per-row int8 error bounds, exact encoded-byte
//!   ledger under freeze/spill/fault churn, encoded-payload bit-identity
//!   across the disk tier), and WAL checkpoint/crash-replay inventory
//!   reproduction, under randomized configs;
//! * **sim-regression** — the paper's headline ordering (LagKV retains
//!   more needle tokens than recency eviction at equal compression) on the
//!   model-free simulator.
//!
//! The tiered-storage properties write only under the system tempdir
//! (removed on drop) — the suite stays hermetic.

use std::collections::HashMap;
use std::sync::Arc;

use lagkv::backend::EngineSpec;
use lagkv::compress::driver::CompressionEvent;
use lagkv::compress::maybe_compress;
use lagkv::compress::policy::{make_policy, Scorer};
use lagkv::compress::topk::{topk_indices, topk_indices_into};
use lagkv::config::{CompressionConfig, PolicyKind};
use lagkv::coordinator::{Event, GenerateParams, Response, Router};
use lagkv::engine::Engine;
use lagkv::kvcache::{ratio, KvCache};
use lagkv::kvpool::{block_bytes, BlockPool, PrefixCache, PrefixConfig};
use lagkv::kvstore::KvStore;
use lagkv::quant::{CodecKind, EncodedKv, QuantSpec};
use lagkv::sim::{self, SimSpec};
use lagkv::util::argmax;
use lagkv::util::prop;
use lagkv::util::rng::Rng;
use lagkv::workloads::passkey::{gen_passkey, PasskeySpec};

fn fill_one(cache: &mut KvCache, rng: &mut Rng) {
    let w = cache.n_layers * cache.n_heads * cache.d_head;
    let t = cache.appended as i32;
    let k: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
    let v: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
    cache.append_token(&k, &v, t).unwrap();
}

/// Eq. 10 must hold not just for the final length but for the *event
/// ledger*: rows evicted across all CompressionEvents reconcile exactly
/// with the closed form, and every partition event evicts the same budget.
#[test]
fn eq10_reconciles_with_compression_event_ledger() {
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        sink: 4,
        lag: 16,
        ratio: 0.25,
        ..Default::default()
    };
    let keep = cfg.keep_per_partition();
    let mut scorer = make_policy(cfg.policy, 0);
    let mut cache = KvCache::new(2, 2, 4);
    let mut rng = Rng::seed_from(41);
    let n = 400usize;
    let mut ledger: Vec<CompressionEvent> = Vec::new();
    for _ in 0..n {
        fill_one(&mut cache, &mut rng);
        ledger.extend(maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap());
    }
    let want = ratio::retained_len(n, cfg.sink, cfg.lag, keep);
    for layer in 0..cache.n_layers {
        assert_eq!(cache.len(layer), want, "layer {layer} violates Eq. 10");
        let evicted: usize = ledger
            .iter()
            .filter(|e| e.layer == layer)
            .map(|e| e.l - e.kept)
            .sum();
        assert_eq!(
            n - evicted,
            cache.len(layer),
            "event ledger does not reconcile with the retained length"
        );
        for e in ledger.iter().filter(|e| e.layer == layer) {
            assert_eq!(e.l, cfg.lag, "partition event width must be L");
            assert_eq!(e.kept, keep, "partition event must keep floor(r*L)");
            assert!(e.start >= cfg.sink, "no event may reach into the sink");
        }
    }
    // and the ratio formula is consistent with the measured length
    let c = ratio::compression_ratio(n, cfg.sink, cfg.lag, keep);
    assert!((c - (1.0 - want as f64 / n as f64)).abs() < 1e-12);
}

/// Same reconciliation for a GLOBAL-scope policy (H2O): window widths vary
/// but the per-event eviction budget is identical, so the ledger still
/// reconciles and Eq. 10 still holds.
#[test]
fn eq10_reconciles_for_global_scope_policy() {
    let cfg = CompressionConfig {
        policy: PolicyKind::H2O,
        sink: 4,
        lag: 16,
        ratio: 0.5,
        ..Default::default()
    };
    let keep = cfg.keep_per_partition();
    let mut scorer = make_policy(cfg.policy, 0);
    let mut cache = KvCache::new(2, 2, 4);
    let mut rng = Rng::seed_from(43);
    let n = 300usize;
    let mut ledger: Vec<CompressionEvent> = Vec::new();
    for _ in 0..n {
        fill_one(&mut cache, &mut rng);
        ledger.extend(maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap());
    }
    let want = ratio::retained_len(n, cfg.sink, cfg.lag, keep);
    for layer in 0..cache.n_layers {
        assert_eq!(cache.len(layer), want, "layer {layer} violates Eq. 10 (global scope)");
        let evicted: usize = ledger
            .iter()
            .filter(|e| e.layer == layer)
            .map(|e| e.l - e.kept)
            .sum();
        assert_eq!(n - evicted, cache.len(layer));
        for e in ledger.iter().filter(|e| e.layer == layer) {
            assert_eq!(e.l - e.kept, cfg.lag - keep, "global events share the budget");
        }
    }
}

/// Streaming appends under any policy/config: sink rows survive, positions
/// stay strictly ascending, and all heads of a layer keep equal lengths
/// (the decode executable's shape contract).
#[test]
fn prop_stream_sink_order_and_head_shape() {
    prop::check(40, |g| {
        let kind = *g.pick(PolicyKind::all());
        let sink = g.usize(0, 5);
        let lag = g.usize(2, 20);
        let ratio = [0.5, 0.25, 0.125][g.usize(0, 2)];
        let n = g.usize(1, 150);
        let cfg = CompressionConfig {
            policy: kind,
            sink,
            lag,
            ratio,
            ..Default::default()
        };
        let mut scorer = make_policy(kind, g.case as u64);
        let mut cache = KvCache::new(2, 3, 2);
        let mut rng = Rng::seed_from(g.case as u64 + 77);
        for _ in 0..n {
            fill_one(&mut cache, &mut rng);
            maybe_compress(&mut cache, &cfg, scorer.as_mut())
                .map_err(|e| format!("driver error: {e:#}"))?;
        }
        for layer in 0..cache.n_layers {
            let len0 = cache.positions(layer, 0).len();
            for head in 0..cache.n_heads {
                let pos = cache.positions(layer, head);
                if pos.len() != len0 {
                    return Err(format!(
                        "{}: head lengths diverged ({} vs {len0})",
                        kind.name(),
                        pos.len()
                    ));
                }
                if pos.windows(2).any(|w| w[0] >= w[1]) {
                    return Err(format!("{}: positions not ascending", kind.name()));
                }
                let s = sink.min(n).min(pos.len());
                for (i, &p) in pos.iter().take(s).enumerate() {
                    if p != i as i32 {
                        return Err(format!("{}: sink row {i} evicted", kind.name()));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Top-k under ties and NaNs: both implementations agree exactly, NaNs are
/// never selected while finite candidates remain, ties resolve to the
/// earliest index, and the output is strictly ascending and in range.
#[test]
fn prop_topk_tie_and_nan_contract() {
    prop::check(150, |g| {
        let n = g.usize(1, 60);
        // quantized scores force ties
        let mut scores: Vec<f32> =
            (0..n).map(|_| (g.f32(-3.0, 3.0) * 4.0).round() / 4.0).collect();
        let n_nan = g.usize(0, n / 2);
        for _ in 0..n_nan {
            let i = g.usize(0, n - 1);
            scores[i] = f32::NAN;
        }
        let k = g.usize(0, n);
        let got = topk_indices(&scores, k);
        let mut scratch = Vec::new();
        let mut fast = Vec::new();
        topk_indices_into(&scores, k, &mut scratch, &mut fast);
        if got != fast {
            return Err(format!("variants disagree: {got:?} vs {fast:?}"));
        }
        if got.len() != k.min(n) {
            return Err(format!("wrong count: {} vs {}", got.len(), k.min(n)));
        }
        if got.windows(2).any(|w| w[0] >= w[1]) {
            return Err("not strictly ascending".into());
        }
        if got.iter().any(|&i| i >= n) {
            return Err("index out of range".into());
        }
        let finite = scores.iter().filter(|s| !s.is_nan()).count();
        let picked_nans = got.iter().filter(|&&i| scores[i].is_nan()).count();
        if k <= finite && picked_nans > 0 {
            return Err(format!(
                "selected {picked_nans} NaNs with {finite} finite candidates for k={k}"
            ));
        }
        if k > finite && picked_nans != k - finite {
            return Err("must fill with NaNs only after finite scores are exhausted".into());
        }
        // tie rule: a selected index never has an unselected smaller index
        // with the same score
        let selected = |i: usize| got.binary_search(&i).is_ok();
        for &i in &got {
            if scores[i].is_nan() {
                continue;
            }
            for j in 0..i {
                if !selected(j) && scores[j] == scores[i] {
                    return Err(format!("tie broke late: kept {i} over earlier {j}"));
                }
            }
        }
        Ok(())
    });
}

/// Stream/one-shot parity across every policy: for a random (policy, L, r,
/// prompt, budget), the live event stream and the folded one-shot response
/// describe the same generation —
/// * concatenated `Token` deltas equal the folded `Response.text`,
/// * the `Token` ids equal `Response.tokens`,
/// * the number of `Compression` events equals `compression_events`,
/// * `Started`/`Done` bracket the stream and agree on the accounting.
#[test]
fn prop_stream_events_fold_to_one_shot_response() {
    let router = Router::start(EngineSpec::cpu(), &["llama_like".to_string()]);
    prop::check(14, |g| {
        let policy = *g.pick(PolicyKind::all());
        let lag = [8usize, 16, 32][g.usize(0, 2)];
        let ratio = [0.5, 0.25, 0.125][g.usize(0, 2)];
        let n_filler = g.usize(40, 150);
        let max_new = g.usize(2, 16);
        let mut rng = Rng::seed_from(g.case as u64 + 5);
        let item =
            gen_passkey(&mut rng, &PasskeySpec { n_filler, n_digits: 8, depth: None });
        let params = GenerateParams::new(item.prompt)
            .policy(policy)
            .sink(4)
            .lag(lag)
            .ratio(ratio)
            .max_new(max_new)
            .seed(g.case as u64);

        // streamed: collect the raw events
        let handle = router
            .submit(
                "llama_like",
                params.clone().into_request(1).map_err(|e| e.to_string())?,
            )
            .map_err(|e| e.to_string())?;
        let events: Vec<Event> = handle.events.iter().collect();

        // one-shot: the folding path callers use
        let folded = router
            .generate(
                "llama_like",
                params.into_request(2).map_err(|e| e.to_string())?,
            )
            .map_err(|e| format!("{e:#}"))?;
        if let Some(err) = &folded.error {
            return Err(format!("{}: one-shot failed: {err}", policy.name()));
        }

        match events.first() {
            Some(Event::Started { prompt_tokens, .. }) => {
                if *prompt_tokens != folded.prompt_tokens {
                    return Err(format!(
                        "Started.prompt_tokens {prompt_tokens} != {}",
                        folded.prompt_tokens
                    ));
                }
            }
            other => return Err(format!("stream must open with Started, got {other:?}")),
        }
        match events.last() {
            Some(Event::Done { usage, .. }) => {
                if usage.cache_lens != folded.cache_lens {
                    return Err("Done.cache_lens diverged from one-shot".into());
                }
                if usage.compression_events != folded.compression_events {
                    return Err("Done.compression_events diverged".into());
                }
            }
            other => return Err(format!("stream must close with Done, got {other:?}")),
        }

        let mut text = String::new();
        let mut tokens = Vec::new();
        let mut n_compress = 0usize;
        for ev in &events {
            match ev {
                Event::Token { token, text_delta, .. } => {
                    tokens.push(*token);
                    text.push_str(text_delta);
                }
                Event::Compression { .. } => n_compress += 1,
                _ => {}
            }
        }
        if text != folded.text {
            return Err(format!(
                "{}: delta concat {text:?} != one-shot text {:?}",
                policy.name(),
                folded.text
            ));
        }
        if tokens != folded.tokens {
            return Err(format!("{}: token ids diverged", policy.name()));
        }
        if n_compress != folded.compression_events {
            return Err(format!(
                "{}: {n_compress} Compression events != {} compression_events",
                policy.name(),
                folded.compression_events
            ));
        }

        // and the generic fold reproduces the one-shot response wholesale
        let refolded = Response::from_events(events);
        if refolded.text != folded.text
            || refolded.tokens != folded.tokens
            || refolded.prompt_tokens != folded.prompt_tokens
            || refolded.cache_lens != folded.cache_lens
            || refolded.compression_events != folded.compression_events
            || refolded.error.is_some()
        {
            return Err("Response::from_events disagrees with Router::generate".into());
        }
        Ok(())
    });
    router.shutdown();
}

/// Wire-protocol property (v1 tentpole): every `api::` shape round-trips
/// `to_json` → `from_json` exactly under randomized contents — generate
/// requests through BOTH the v1 envelope and the legacy compat shim,
/// control-plane requests, events, responses, and typed errors — and an
/// injected unknown field is always a `bad-params` rejection naming the
/// key.
#[test]
fn prop_api_wire_shapes_round_trip_exactly() {
    use lagkv::api::{self, ApiRequest, CancelRequest, GenerateRequest, SessionsRequest};
    use lagkv::config::ScorerBackend;
    use lagkv::coordinator::{ApiError, Timings, Usage};
    use lagkv::util::json::Json;

    prop::check(60, |g| {
        // --- generate request, v1 envelope and legacy dialect ---
        let mut params = GenerateParams::new(format!("prompt {} with spaces", g.usize(0, 999)))
            .model(["llama_like", "qwen_like"][g.usize(0, 1)])
            .policy(*g.pick(PolicyKind::all()))
            .sink(g.usize(0, 8))
            .lag(g.usize(1, 128))
            .ratio([0.5, 0.25, 0.167, 0.125, 1.0][g.usize(0, 4)])
            .max_new(g.usize(1, 600))
            .seed(g.usize(0, 1 << 30) as u64);
        if g.bool() {
            params = params.scorer(ScorerBackend::Xla);
        }
        if g.bool() {
            params = params.skip_layers(g.usize(0, 3));
        }
        if g.bool() {
            params = params.session(format!("chat-{}", g.usize(0, 99)));
        }
        let req = GenerateRequest {
            id: if g.bool() { Some(g.usize(0, 1 << 20) as u64) } else { None },
            stream: g.bool(),
            params,
        };
        let v1 = req.to_json().to_string();
        match api::parse_line(&v1).map_err(|e| e.to_string())? {
            ApiRequest::Generate(back) if back == req => {}
            other => return Err(format!("v1 round-trip mismatch: {other:?} vs {req:?}")),
        }
        let legacy = req.to_legacy_json().to_string();
        match api::parse_line(&legacy).map_err(|e| e.to_string())? {
            ApiRequest::Generate(back) if back == req => {}
            other => return Err(format!("legacy shim mismatch: {other:?}")),
        }

        // --- unknown-field rejection names the key, both dialects ---
        for line in [&v1, &legacy] {
            let mut m = Json::parse(line).unwrap().as_obj().unwrap().clone();
            m.insert("bogus_key".to_string(), Json::Bool(true));
            match api::parse_line(&Json::Obj(m).to_string()) {
                Err(e) if e.code() == "bad-params" && e.message().contains("bogus_key") => {}
                other => return Err(format!("unknown field not rejected: {other:?}")),
            }
        }

        // --- control-plane requests ---
        let reqs = [
            ApiRequest::Cancel(CancelRequest { id: g.usize(0, 1 << 20) as u64 }),
            ApiRequest::Sessions(SessionsRequest {
                model: g.bool().then(|| "llama_like".to_string()),
                delete: g.bool().then(|| format!("chat-{}", g.usize(0, 9))),
            }),
            ApiRequest::Stats(api::StatsRequest),
            ApiRequest::Info(api::InfoRequest),
            ApiRequest::Drain(api::DrainRequest),
            ApiRequest::Undrain(api::UndrainRequest),
            ApiRequest::Checkpoint(api::CheckpointRequest),
            ApiRequest::Trace(api::TraceRequest),
        ];
        for r in &reqs {
            let line = r.to_json().to_string();
            match api::parse_line(&line).map_err(|e| e.to_string())? {
                back if &back == r => {}
                other => return Err(format!("op round-trip mismatch: {other:?} vs {r:?}")),
            }
        }

        // --- typed errors ---
        let errors = [
            ApiError::QueueFull { model: format!("m{}", g.usize(0, 9)) },
            ApiError::PoolExhausted {
                model: "m".into(),
                detail: format!("need {} bytes", g.usize(1, 1 << 20)),
            },
            ApiError::UnknownModel {
                model: "x".into(),
                have: vec!["llama_like".into(), "qwen_like".into()],
            },
            ApiError::BadParams { message: format!("bad {}", g.usize(0, 9)) },
            ApiError::EngineFailure { message: "boom".into() },
            ApiError::Cancelled,
            ApiError::Draining { model: "m".into() },
        ];
        for e in &errors {
            let back = ApiError::from_json(&Json::parse(&e.to_json().to_string()).unwrap())
                .map_err(|x| x.to_string())?;
            if &back != e {
                return Err(format!("error round-trip mismatch: {back:?} vs {e:?}"));
            }
        }

        // --- events ---
        let usage = Usage {
            prompt_tokens: g.usize(0, 600),
            new_tokens: g.usize(0, 80),
            reused_tokens: g.usize(0, 600),
            cache_lens: (0..g.usize(1, 4)).map(|_| g.usize(0, 999)).collect(),
            compression_events: g.usize(0, 30),
        };
        let timings = Timings {
            queue_us: g.usize(0, 1 << 20) as u64,
            prefill_us: g.usize(0, 1 << 20) as u64,
            decode_us: g.usize(0, 1 << 20) as u64,
        };
        let id = g.usize(0, 1 << 20) as u64;
        let events = [
            Event::Started { id, prompt_tokens: usage.prompt_tokens, reused_tokens: 3 },
            Event::Token {
                id,
                token: g.usize(0, 5000) as i32,
                text_delta: format!(" tok{}", g.usize(0, 99)),
            },
            Event::Compression {
                id,
                layer_lens: usage.cache_lens.clone(),
                evicted: g.usize(0, 64),
            },
            Event::Done { id, usage: usage.clone(), timings: timings.clone() },
            Event::Error { id, error: errors[g.usize(0, errors.len() - 1)].clone() },
        ];
        for ev in &events {
            let back = api::event_from_json(&Json::parse(&api::event_line(ev)).unwrap())
                .map_err(|x| x.to_string())?;
            if &back != ev {
                return Err(format!("event round-trip mismatch: {back:?} vs {ev:?}"));
            }
        }

        // --- one-shot responses ---
        let resp = Response {
            id,
            text: format!("text {}", g.usize(0, 99)),
            tokens: (0..usage.new_tokens).map(|_| g.usize(0, 5000) as i32).collect(),
            prompt_tokens: usage.prompt_tokens,
            reused_tokens: usage.reused_tokens,
            cache_lens: usage.cache_lens.clone(),
            compression_events: usage.compression_events,
            queue_us: timings.queue_us,
            prefill_us: timings.prefill_us,
            decode_us: timings.decode_us,
            error: g.bool().then(|| errors[g.usize(0, errors.len() - 1)].clone()),
        };
        let back = api::response_from_json(&Json::parse(&api::response_line(&resp)).unwrap())
            .map_err(|x| x.to_string())?;
        if back != resp {
            return Err(format!("response round-trip mismatch: {back:?} vs {resp:?}"));
        }

        // --- trace response: randomized spans + histogram summaries ---
        use lagkv::api::{ModelTrace, TraceResponse};
        use lagkv::telemetry::{HistogramSummary, Metric, Span, SpanEvent, SpanEventKind};
        let kinds = [
            SpanEventKind::Queued,
            SpanEventKind::Admitted,
            SpanEventKind::SessionResume,
            SpanEventKind::PrefillSegment,
            SpanEventKind::FirstToken,
            SpanEventKind::DecodeStep,
            SpanEventKind::Compression,
            SpanEventKind::SpillStall,
            SpanEventKind::Done,
            SpanEventKind::Cancelled,
            SpanEventKind::Failed,
        ];
        let mut t = 0u64;
        let spans: Vec<Span> = (0..g.usize(0, 3))
            .map(|i| Span {
                id: i as u64 + 1,
                events: (0..g.usize(1, 6))
                    .map(|_| {
                        t += g.usize(1, 900) as u64;
                        SpanEvent {
                            t_us: t,
                            kind: *g.pick(&kinds),
                            value: g.usize(0, 1 << 20) as u64,
                        }
                    })
                    .collect(),
            })
            .collect();
        let histograms: Vec<HistogramSummary> = Metric::all()
            .iter()
            .filter(|_| g.bool())
            .map(|m| {
                let p50 = g.usize(0, 1 << 20) as u64;
                HistogramSummary {
                    metric: *m,
                    count: g.usize(1, 1 << 20) as u64,
                    p50_us: p50,
                    p90_us: p50 + g.usize(0, 1 << 10) as u64,
                    p99_us: p50 + g.usize(0, 1 << 12) as u64,
                }
            })
            .collect();
        let trace = TraceResponse {
            models: vec![ModelTrace {
                model: ["llama_like", "qwen_like"][g.usize(0, 1)].to_string(),
                dropped_events: g.usize(0, 99) as u64,
                spans,
                histograms,
            }],
        };
        let v = Json::parse(&trace.to_json().to_string()).unwrap();
        let back = TraceResponse::from_json(&v).map_err(|x| x.to_string())?;
        if back != trace {
            return Err(format!("trace round-trip mismatch: {back:?} vs {trace:?}"));
        }
        // unknown keys are rejected at every nesting level of the payload
        for line in [
            // inside a span event
            r#"{"v":1,"op":"trace","models":[{"model":"m","dropped_events":0,
               "spans":[{"id":1,"events":[{"t_us":1,"kind":"queued","value":0,"bogus_key":1}]}],
               "histograms":[]}]}"#,
            // inside a span
            r#"{"v":1,"op":"trace","models":[{"model":"m","dropped_events":0,
               "spans":[{"id":1,"events":[],"bogus_key":1}],"histograms":[]}]}"#,
            // inside a histogram summary
            r#"{"v":1,"op":"trace","models":[{"model":"m","dropped_events":0,"spans":[],
               "histograms":[{"metric":"ttft","count":1,"p50_us":1,"p90_us":1,"p99_us":1,
               "bogus_key":1}]}]}"#,
        ] {
            if TraceResponse::from_json(&Json::parse(line).unwrap()).is_ok() {
                return Err(format!("unknown field accepted in {line}"));
            }
        }
        // and an unknown key on the trace *request* is a typed rejection
        match api::parse_line(r#"{"v":1,"op":"trace","bogus_key":1}"#) {
            Err(e) if e.code() == "bad-params" && e.message().contains("bogus_key") => {}
            other => return Err(format!("trace request unknown field: {other:?}")),
        }
        Ok(())
    });
}

/// Telemetry sink property: publishing is provably non-blocking.  A
/// publisher racing a drainer always makes progress (no deadlock, no
/// waiting on the sink lock), and every span is accounted for exactly —
/// `published + dropped == submitted` — whether it was refused by a full
/// ring or a contended lock.  With no drainer at all, a ring of capacity
/// `k` accepts exactly `k` spans and drops the rest, counted exactly.
#[test]
fn prop_trace_publish_never_blocks_and_counts_drops_exactly() {
    use lagkv::telemetry::{EventSink, Span, SpanEvent, SpanEventKind};

    fn span(id: u64) -> Span {
        Span {
            id,
            events: vec![SpanEvent { t_us: id, kind: SpanEventKind::Done, value: 0 }],
        }
    }

    prop::check(12, |g| {
        // --- overflow with no drainer: exact capacity split ---
        let cap = g.usize(1, 16);
        let total = cap + g.usize(1, 32);
        let sink = EventSink::new(cap, 4, None);
        let accepted = (0..total).filter(|&i| sink.try_publish(span(i as u64))).count();
        if accepted != cap {
            return Err(format!("ring of {cap} accepted {accepted}"));
        }
        if sink.published() != cap as u64 || sink.dropped() != (total - cap) as u64 {
            return Err(format!(
                "ledger off: published {} dropped {} of {total}",
                sink.published(),
                sink.dropped()
            ));
        }

        // --- publisher vs. drainer race: progress + exact accounting ---
        let sink = Arc::new(EventSink::new(g.usize(1, 8), 4, None));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let drainer = {
            let sink = Arc::clone(&sink);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut drained = 0usize;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    drained += sink.drain();
                }
                drained + sink.drain()
            })
        };
        let total = g.usize(50, 400);
        let t0 = std::time::Instant::now();
        let mut published = 0u64;
        for i in 0..total {
            if sink.try_publish(span(i as u64)) {
                published += 1;
            }
        }
        let elapsed = t0.elapsed();
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let drained = drainer.join().unwrap();
        // Progress: publishing N spans against a contended lock must never
        // stall; a generous wall-clock bound catches an accidental
        // blocking lock (which would serialize behind the drain loop).
        if elapsed > std::time::Duration::from_secs(5) {
            return Err(format!("publisher stalled: {total} publishes took {elapsed:?}"));
        }
        if sink.published() != published || published + sink.dropped() != total as u64 {
            return Err(format!(
                "accounting off: {published} accepted + {} dropped != {total}",
                sink.dropped()
            ));
        }
        if (drained as u64) != published {
            return Err(format!("drained {drained} != accepted {published}"));
        }
        Ok(())
    });
}

/// Allocator invariants under arbitrary append / compress / detach-clone /
/// drop / freeze / thaw / shed interleavings on one shared pool: the
/// loose-byte ledger never saturates or silently underflows mid-run (a
/// `saturating_sub` masked exactly that bug), and when every owner is gone
/// the refcount ledger reconciles to zero (no block leaks, no stray loose
/// bytes) with every frozen block recycled through the free list.
///
/// Thaw is exercised by mixing in a GLOBAL-scope policy (its compaction
/// windows reach behind the frozen boundary); shed by a prefix tree on the
/// same pool absorbing snapshots and dropping them LRU-first.
#[test]
fn prop_pool_ledger_reconciles_after_interleavings() {
    prop::check(25, |g| {
        let pool = BlockPool::unbounded(4);
        let d = g.usize(1, 3);
        let nh = g.usize(1, 2);
        let cfg = CompressionConfig {
            // H2O's global scope thaws frozen blocks during compaction —
            // the ledger path the b=1 sweep's underflow fix guards.
            policy: [PolicyKind::LagKv, PolicyKind::H2O][g.usize(0, 1)],
            sink: g.usize(0, 4),
            lag: [4usize, 8, 12][g.usize(0, 2)],
            ratio: 0.5,
            ..Default::default()
        };
        let prefix = PrefixCache::new(
            PrefixConfig { max_entries: 3, max_bytes: 0, stride: 4 },
            pool.clone(),
        );
        let mut scorer = make_policy(cfg.policy, g.case as u64);
        let mut rng = Rng::seed_from(g.case as u64 + 31);
        let mut caches = vec![KvCache::new_in(pool.clone(), 1, nh, d)];
        let mut froze_any = false;
        for _ in 0..g.usize(20, 140) {
            match g.usize(0, 9) {
                0..=5 => {
                    let i = g.usize(0, caches.len() - 1);
                    let w = nh * d;
                    let t = caches[i].appended as i32;
                    let k: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
                    caches[i].append_token(&k, &k, t).unwrap();
                    maybe_compress(&mut caches[i], &cfg, scorer.as_mut())
                        .map_err(|e| format!("driver: {e:#}"))?;
                    froze_any |= caches[i].frozen_blocks() > 0;
                }
                6..=7 => {
                    // detach-style clone: shares frozen blocks CoW
                    if caches.len() < 4 {
                        let i = g.usize(0, caches.len() - 1);
                        let c = caches[i].clone();
                        caches.push(c);
                    }
                }
                8 => {
                    // freeze a snapshot into the prefix tree, or shed one
                    if g.bool() {
                        let i = g.usize(0, caches.len() - 1);
                        let n = caches[i].appended;
                        if n > 0 {
                            let key: Vec<i32> = (0..n.min(g.usize(1, 10)))
                                .map(|t| t as i32)
                                .collect();
                            prefix.insert(&cfg, 0, &key, &caches[i]);
                        }
                    } else {
                        let _ = prefix.shed_lru();
                    }
                }
                _ => {
                    if caches.len() > 1 {
                        let i = g.usize(0, caches.len() - 1);
                        caches.swap_remove(i);
                    }
                }
            }
            // Mid-run ledger sanity after EVERY op.  A wrapped subtraction
            // would land loose_bytes near usize::MAX; a silently clamped
            // one (the old `saturating_sub` mask) drops the pool's
            // resident total below the footprint of a single live owner.
            let s = pool.stats();
            if s.loose_bytes > usize::MAX / 2 {
                return Err(format!("loose-byte ledger saturated: {}", s.loose_bytes));
            }
            let biggest = caches.iter().map(|c| c.exact_bytes()).max().unwrap_or(0);
            if s.resident_bytes() < biggest {
                return Err(format!(
                    "ledger lost bytes: pool resident {} below a single cache's {biggest}",
                    s.resident_bytes()
                ));
            }
            let owned: usize = caches.iter().map(|c| c.exact_bytes()).sum();
            if s.resident_bytes() > owned + prefix.stats().resident_bytes {
                return Err(format!(
                    "pool resident {} exceeds every owner's footprint ({owned} + tree {})",
                    s.resident_bytes(),
                    prefix.stats().resident_bytes
                ));
            }
        }
        drop(prefix);
        // with a single never-cloned cache the pool count is exactly its
        // reference count; with clones it can only be smaller (sharing)
        let refs: usize = caches.iter().map(|c| c.frozen_blocks()).sum();
        let live = pool.stats();
        if live.resident_blocks > refs {
            return Err(format!(
                "pool holds {} blocks but caches reference only {refs}",
                live.resident_blocks
            ));
        }
        caches.clear();
        let s = pool.stats();
        if s.resident_blocks != 0 {
            return Err(format!("{} blocks leaked", s.resident_blocks));
        }
        if s.resident_bytes() != 0 {
            return Err(format!("{} resident bytes leaked", s.resident_bytes()));
        }
        if froze_any && s.free_blocks == 0 {
            return Err("frozen blocks were not recycled to the free list".into());
        }
        Ok(())
    });
}

/// The old flat per-head rebuild, kept as the semantic reference: the
/// pooled block-remap (freeze + loose rebuild + thaw-on-demand) must match
/// it bit-for-bit under random append/compact interleavings, including
/// windows that reach behind the frozen boundary.
///
/// A sibling copy lives in benches/perf_hotpath.rs as the *timing*
/// baseline; both are deliberately verbatim transcriptions of the
/// pre-kvpool `compact_window` — change neither without the other.
struct FlatHead {
    k: Vec<f32>,
    v: Vec<f32>,
    pos: Vec<i32>,
    attn: Vec<f32>,
}

impl FlatHead {
    fn compact_window(&mut self, d: usize, start: usize, l: usize, keep: &[usize]) {
        let mut k = Vec::new();
        let mut v = Vec::new();
        let mut pos = Vec::new();
        let mut attn = Vec::new();
        k.extend_from_slice(&self.k[..start * d]);
        v.extend_from_slice(&self.v[..start * d]);
        pos.extend_from_slice(&self.pos[..start]);
        attn.extend_from_slice(&self.attn[..start]);
        for &i in keep {
            let r = start + i;
            k.extend_from_slice(&self.k[r * d..(r + 1) * d]);
            v.extend_from_slice(&self.v[r * d..(r + 1) * d]);
            pos.push(self.pos[r]);
            attn.push(self.attn[r]);
        }
        k.extend_from_slice(&self.k[(start + l) * d..]);
        v.extend_from_slice(&self.v[(start + l) * d..]);
        pos.extend_from_slice(&self.pos[start + l..]);
        attn.extend_from_slice(&self.attn[start + l..]);
        self.k = k;
        self.v = v;
        self.pos = pos;
        self.attn = attn;
    }
}

#[test]
fn prop_pooled_compact_matches_flat_rebuild_bit_for_bit() {
    prop::check(40, |g| {
        let d = g.usize(1, 4);
        let nh = g.usize(1, 3);
        let pool = BlockPool::unbounded(g.usize(2, 6));
        let mut cache = KvCache::new_in(pool, 1, nh, d);
        let mut flat: Vec<FlatHead> = (0..nh)
            .map(|_| FlatHead { k: vec![], v: vec![], pos: vec![], attn: vec![] })
            .collect();
        let mut rng = Rng::seed_from(g.case as u64 + 101);
        for _ in 0..g.usize(10, 80) {
            let len = cache.len(0);
            if len < 4 || g.bool() {
                let w = nh * d;
                let t = cache.appended as i32;
                let k: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
                let v: Vec<f32> = (0..w).map(|_| rng.normal()).collect();
                cache.append_token(&k, &v, t).unwrap();
                for (h, fh) in flat.iter_mut().enumerate() {
                    let off = h * d;
                    fh.k.extend_from_slice(&k[off..off + d]);
                    fh.v.extend_from_slice(&v[off..off + d]);
                    fh.pos.push(t);
                    fh.attn.push(0.0);
                }
            } else {
                let l = g.usize(1, (len - 1).min(8));
                let start = g.usize(0, len - l);
                let kept = g.usize(1, l);
                let keeps: Vec<Vec<usize>> = (0..nh)
                    .map(|_| {
                        let mut ks = rng.choose_distinct(l, kept);
                        ks.sort_unstable();
                        ks
                    })
                    .collect();
                cache
                    .compact_layer(0, start, l, &keeps)
                    .map_err(|e| format!("compact: {e:#}"))?;
                for (h, fh) in flat.iter_mut().enumerate() {
                    fh.compact_window(d, start, l, &keeps[h]);
                }
            }
        }
        for (h, fh) in flat.iter().enumerate() {
            if cache.head_k(0, h) != fh.k {
                return Err(format!("head {h}: keys diverged from the flat reference"));
            }
            if cache.head_v(0, h) != fh.v {
                return Err(format!("head {h}: values diverged"));
            }
            if cache.positions(0, h) != fh.pos {
                return Err(format!("head {h}: positions diverged"));
            }
            if cache.head_attn(0, h) != fh.attn {
                return Err(format!("head {h}: attention mass diverged"));
            }
        }
        Ok(())
    });
}

/// Copy-on-write: a detached clone's contents survive arbitrary further
/// mutation of the original — shared frozen blocks are never written.
#[test]
fn prop_cow_snapshots_survive_original_mutation() {
    prop::check(15, |g| {
        let pool = BlockPool::unbounded(4);
        let cfg = CompressionConfig {
            policy: PolicyKind::LagKv,
            sink: g.usize(0, 3),
            lag: [4usize, 8][g.usize(0, 1)],
            ratio: 0.5,
            ..Default::default()
        };
        let mut scorer = make_policy(cfg.policy, g.case as u64);
        let mut rng = Rng::seed_from(g.case as u64 + 57);
        let mut cache = KvCache::new_in(pool.clone(), 1, 2, 3);
        let mut feed = |cache: &mut KvCache, rng: &mut Rng, n: usize| -> Result<(), String> {
            for _ in 0..n {
                let t = cache.appended as i32;
                let k: Vec<f32> = (0..2 * 3).map(|_| rng.normal()).collect();
                cache.append_token(&k, &k, t).unwrap();
                maybe_compress(cache, &cfg, scorer.as_mut())
                    .map_err(|e| format!("driver: {e:#}"))?;
            }
            Ok(())
        };
        feed(&mut cache, &mut rng, g.usize(30, 80))?;
        let snap_k = cache.head_k(0, 0);
        let snap_v = cache.head_v(0, 1);
        let snap_pos = cache.positions(0, 0);
        let shared_blocks = cache.frozen_blocks();
        let clone = cache.clone();
        if pool.stats().resident_blocks != shared_blocks {
            return Err(format!(
                "clone duplicated blocks: pool {} vs {shared_blocks} shared",
                pool.stats().resident_blocks
            ));
        }
        feed(&mut cache, &mut rng, g.usize(10, 60))?;
        if clone.head_k(0, 0) != snap_k {
            return Err("clone keys changed under original mutation".into());
        }
        if clone.head_v(0, 1) != snap_v {
            return Err("clone values changed under original mutation".into());
        }
        if clone.positions(0, 0) != snap_pos {
            return Err("clone positions changed under original mutation".into());
        }
        drop(cache);
        if clone.head_k(0, 0) != snap_k {
            return Err("clone lost shared blocks when the original dropped".into());
        }
        Ok(())
    });
}

/// The acceptance bound for CoW sessions: a 2-turn resume through
/// `prefill_onto` allocates only tail/new-turn blocks and never deep-copies
/// the reattached history (pool high-water would betray a copy).
#[test]
fn session_resume_allocates_only_tail_blocks() {
    let engine = Engine::cpu_ref("llama_like").unwrap();
    let pool = engine.pool().clone();
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        sink: 4,
        lag: 16,
        ratio: 0.25,
        ..Default::default()
    };
    let mut rng = Rng::seed_from(23);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 280, n_digits: 16, depth: None });
    let ids = engine.tokenizer.encode(&item.prompt, true);
    let (logits, mut cache) = engine.prefill(&ids).unwrap();
    let mut scorer = engine.make_scorer(&cfg, 0);
    maybe_compress(&mut cache, &cfg, scorer.as_mut()).unwrap();
    assert!(cache.frozen_blocks() > 0, "turn 1 must have paged its prefix");
    let history_blocks = cache.frozen_blocks();
    let history_bytes = cache.exact_bytes();
    let before = pool.stats();

    // turn 2: the pending token plus the new turn's text, decode path
    let first = argmax(&logits) as i32;
    let mut feed = vec![first];
    feed.extend(engine.tokenizer.encode("<q> the pass key <a>", false));
    engine.prefill_onto(&mut cache, &cfg, scorer.as_mut(), &feed).unwrap();
    let after = pool.stats();

    // every new pool block is the resumed cache's own tail growth
    let grown = after.resident_blocks - before.resident_blocks;
    assert_eq!(
        grown,
        cache.frozen_blocks() - history_blocks,
        "resume allocated blocks that are not its own tail"
    );
    // the tail growth is bounded by the new tokens plus one lag window of
    // slack per layer — nowhere near a history copy
    let rpb = pool.rows_per_block();
    let row_cap = feed.len() + 2 * cfg.lag + rpb;
    assert!(
        grown * rpb <= cache.n_layers * cache.n_heads * row_cap,
        "{grown} new blocks is more than the new turn could need"
    );
    // and the high-water mark moved by much less than a full history copy
    let hw_growth = after.high_water_bytes - before.high_water_bytes;
    assert!(
        hw_growth < history_bytes / 2,
        "high-water grew {hw_growth} B against a {history_bytes} B history: \
         something deep-copied the cache on resume"
    );
}

/// Prefix-cache parity across EVERY policy: generation through a warm
/// radix prefix cache — both the segmented cold path that seeds it and a
/// genuine prefix hit — must decode bit-identically to a cache-less
/// engine.  Attention-fed policies (H2O) are path-dependent, so for them
/// the contract is a verified *bypass* (the tree never engages), which
/// makes the parity trivial — exactly the paper's attention-free
/// integration argument.
#[test]
fn prefix_hit_decode_matches_cold_prefill_for_every_policy() {
    let mut rng = Rng::seed_from(61);
    let sys = gen_passkey(&mut rng, &PasskeySpec { n_filler: 60, n_digits: 16, depth: None })
        .prompt;
    for &policy in PolicyKind::all() {
        let mut warm = Engine::cpu_ref("llama_like").unwrap();
        let prefix =
            warm.enable_prefix_cache(PrefixConfig { stride: 16, ..Default::default() });
        let cold = Engine::cpu_ref("llama_like").unwrap();
        let cfg = CompressionConfig {
            policy,
            sink: 4,
            lag: 8,
            ratio: 0.5,
            skip_layers: if policy == PolicyKind::L2Norm { 1 } else { 0 },
            ..Default::default()
        };
        let ids_sys = warm.tokenizer.encode(&sys, true);
        let tail1 = warm.tokenizer.encode("<q> the pass key <a>", false);
        let tail2 = warm.tokenizer.encode("<q> remember the words <a>", false);
        let ids1: Vec<i32> = ids_sys.iter().chain(tail1.iter()).copied().collect();
        let ids2: Vec<i32> = ids_sys.iter().chain(tail2.iter()).copied().collect();

        // seeding request: segmented-ingest cold path == classic cold path
        let w1 = warm.generate_ids(&ids1, &cfg, 6, 3).unwrap();
        let c1 = cold.generate_ids(&ids1, &cfg, 6, 3).unwrap();
        assert_eq!(w1.tokens, c1.tokens, "{}: segmented prefill diverged", policy.name());
        assert_eq!(w1.cache_lens, c1.cache_lens, "{}", policy.name());

        // shared-prefix request: hit path == cold path, bit for bit
        let w2 = warm.generate_ids(&ids2, &cfg, 6, 3).unwrap();
        let c2 = cold.generate_ids(&ids2, &cfg, 6, 3).unwrap();
        assert_eq!(w2.tokens, c2.tokens, "{}: prefix-hit decode diverged", policy.name());
        assert_eq!(w2.text, c2.text, "{}", policy.name());
        assert_eq!(w2.cache_lens, c2.cache_lens, "{}", policy.name());

        let s = prefix.stats();
        if policy.needs_attention() {
            assert_eq!(s.entries, 0, "{}: path-dependent policy must bypass", policy.name());
            assert_eq!(w2.reused_tokens, 0, "{}", policy.name());
        } else {
            assert!(s.hits >= 1, "{}: shared prefix must hit ({s:?})", policy.name());
            assert!(w2.reused_tokens > 0, "{}", policy.name());
        }
    }
}

/// The b=1-kill acceptance pin: the packed wide-bucket suffix walk
/// (`prefill_onto_batched`) must be **bit-identical** to the incremental
/// b=1 walk (`prefill_onto`) — same logits, same compression-event
/// trajectory, same cache contents row for row — across every policy and
/// randomized (sink, L, r, history, suffix).  The continuous batcher's
/// session resume and the prefix cache's warm path both lean on this
/// equivalence; attention-fed policies exercise the documented fallback
/// (the packed path detects them and routes through b=1 itself).
#[test]
fn prop_prefill_onto_batched_matches_b1_bit_for_bit() {
    prop::check(12, |g| {
        let policy = *g.pick(PolicyKind::all());
        let cfg = CompressionConfig {
            policy,
            sink: g.usize(0, 4),
            lag: [4usize, 8, 16][g.usize(0, 2)],
            ratio: [0.5, 0.25][g.usize(0, 1)],
            ..Default::default()
        };
        let eng_a = Engine::cpu_ref("llama_like").unwrap();
        let eng_b = Engine::cpu_ref("llama_like").unwrap();
        let mut rng = Rng::seed_from(g.case as u64 + 9);
        let item = gen_passkey(
            &mut rng,
            &PasskeySpec { n_filler: g.usize(30, 90), n_digits: 8, depth: None },
        );
        let base = eng_a.tokenizer.encode(&item.prompt, true);
        let mut suffix = eng_a.tokenizer.encode("<q> the pass key <a>", false);
        for _ in 0..g.usize(0, 2) {
            suffix.extend(eng_a.tokenizer.encode("<q> remember the words <a>", false));
        }
        let (_, mut cache_a) = eng_a.prefill(&base).map_err(|e| format!("{e:#}"))?;
        let (_, mut cache_b) = eng_b.prefill(&base).map_err(|e| format!("{e:#}"))?;
        let mut sc_a = eng_a.make_scorer(&cfg, g.case as u64);
        let mut sc_b = eng_b.make_scorer(&cfg, g.case as u64);
        maybe_compress(&mut cache_a, &cfg, sc_a.as_mut())
            .map_err(|e| format!("driver a: {e:#}"))?;
        maybe_compress(&mut cache_b, &cfg, sc_b.as_mut())
            .map_err(|e| format!("driver b: {e:#}"))?;

        let (la, ea) = eng_a
            .prefill_onto(&mut cache_a, &cfg, sc_a.as_mut(), &suffix)
            .map_err(|e| format!("b=1 walk: {e:#}"))?;
        let (lb, eb) = eng_b
            .prefill_onto_batched(&mut cache_b, &cfg, sc_b.as_mut(), &suffix)
            .map_err(|e| format!("packed walk: {e:#}"))?;

        if la != lb {
            return Err(format!("{}: final logits diverged", policy.name()));
        }
        if ea != eb {
            return Err(format!(
                "{}: compression trajectories diverged ({} vs {} events)",
                policy.name(),
                ea.len(),
                eb.len()
            ));
        }
        if cache_a.appended != cache_b.appended {
            return Err(format!(
                "{}: appended counters diverged ({} vs {})",
                policy.name(),
                cache_a.appended,
                cache_b.appended
            ));
        }
        for layer in 0..cache_a.n_layers {
            if cache_a.len(layer) != cache_b.len(layer) {
                return Err(format!("{}: layer {layer} lengths diverged", policy.name()));
            }
            for head in 0..cache_a.n_heads {
                if cache_a.positions(layer, head) != cache_b.positions(layer, head) {
                    return Err(format!(
                        "{}: layer {layer} head {head} positions diverged",
                        policy.name()
                    ));
                }
                if cache_a.head_k(layer, head) != cache_b.head_k(layer, head) {
                    return Err(format!(
                        "{}: layer {layer} head {head} keys diverged",
                        policy.name()
                    ));
                }
                if cache_a.head_v(layer, head) != cache_b.head_v(layer, head) {
                    return Err(format!(
                        "{}: layer {layer} head {head} values diverged",
                        policy.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Prefix-tree ledger under randomized insert / hit / evict churn on one
/// shared pool: the tree's byte counter always equals the sum of its
/// entries, caps hold, and when the tree and every attached clone are
/// gone the pool ledger reconciles to zero — no block leak, no
/// double-free, recycled buffers bounded by the high-water mark.
#[test]
fn prop_prefix_tree_ledger_reconciles_under_churn() {
    prop::check(20, |g| {
        let pool = BlockPool::unbounded(4);
        let max_entries = g.usize(1, 6);
        let prefix = PrefixCache::new(
            PrefixConfig { max_entries, max_bytes: 0, stride: 8 },
            pool.clone(),
        );
        let cfg = CompressionConfig {
            policy: PolicyKind::LagKv,
            sink: 2,
            lag: 4,
            ratio: 0.5,
            ..Default::default()
        };
        let mut scorer = make_policy(cfg.policy, g.case as u64);
        let mut rng = Rng::seed_from(g.case as u64 + 11);
        // a small token alphabet forces shared prefixes and edge splits
        let mut attached: Vec<KvCache> = Vec::new();
        for _ in 0..g.usize(15, 60) {
            let key: Vec<i32> = (0..g.usize(1, 12)).map(|_| g.usize(0, 3) as i32).collect();
            match g.usize(0, 5) {
                0..=2 => {
                    // build a cache shaped like the key and insert it
                    let mut c = KvCache::new_in(pool.clone(), 1, 1, 2);
                    for t in 0..key.len() + g.usize(0, 20) {
                        let k: Vec<f32> = (0..2).map(|_| rng.normal()).collect();
                        c.append_token(&k, &k, t as i32).unwrap();
                        maybe_compress(&mut c, &cfg, scorer.as_mut())
                            .map_err(|e| format!("driver: {e:#}"))?;
                    }
                    prefix.insert(&cfg, 0, &key, &c);
                }
                3..=4 => {
                    if let Some((cache, depth)) = prefix.lookup(&cfg, 0, &key) {
                        if depth >= key.len() {
                            return Err(format!(
                                "matched depth {depth} is not a proper prefix of {key:?}"
                            ));
                        }
                        if attached.len() < 4 && g.bool() {
                            attached.push(cache);
                        }
                    }
                }
                _ => {
                    let _ = prefix.shed_lru();
                }
            }
            let s = prefix.stats();
            if s.entries > max_entries {
                return Err(format!("{} entries exceed cap {max_entries}", s.entries));
            }
            if s.entries == 0 && s.resident_bytes != 0 {
                return Err("empty tree holds bytes".into());
            }
            if pool.sheddable_bytes() != s.resident_bytes {
                return Err("prefix sheddable gauge out of step with the tree".into());
            }
        }
        attached.clear();
        drop(prefix);
        let s = pool.stats();
        if s.resident_blocks != 0 {
            return Err(format!("{} blocks leaked", s.resident_blocks));
        }
        if s.resident_bytes() != 0 {
            return Err(format!("{} resident bytes leaked", s.resident_bytes()));
        }
        if s.free_bytes > s.high_water_bytes {
            return Err("free list grew past the high-water mark".into());
        }
        Ok(())
    });
}

/// Unique scratch directory under the system tempdir, removed on drop —
/// the tiered-storage properties stay hermetic like everything else here
/// (kvstore's own TempDir helper is crate-internal).
struct TestDir(std::path::PathBuf);

impl TestDir {
    fn new(tag: &str) -> TestDir {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let n = SEQ.fetch_add(1, Ordering::Relaxed);
        let path =
            std::env::temp_dir().join(format!("lagkv-prop-{tag}-{}-{n}", std::process::id()));
        std::fs::create_dir_all(&path).unwrap();
        TestDir(path)
    }

    fn path(&self) -> &std::path::Path {
        &self.0
    }
}

impl Drop for TestDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Every head's full contents of a 1-layer cache, gathered through the
/// fault-in path (the gather itself promotes spilled blocks).
type HeadSnap = (Vec<f32>, Vec<f32>, Vec<i32>, Vec<f32>);

fn tier_snap(c: &KvCache) -> Vec<HeadSnap> {
    (0..c.n_heads)
        .map(|h| (c.head_k(0, h), c.head_v(0, h), c.positions(0, h), c.head_attn(0, h)))
        .collect()
}

fn grown_cache(
    pool: &Arc<BlockPool>,
    d: usize,
    n: usize,
    cfg: &CompressionConfig,
    scorer: &mut dyn Scorer,
    rng: &mut Rng,
) -> Result<KvCache, String> {
    let mut c = KvCache::new_in(Arc::clone(pool), 1, 1, d);
    for _ in 0..n {
        fill_one(&mut c, rng);
        maybe_compress(&mut c, cfg, scorer).map_err(|e| format!("driver: {e:#}"))?;
    }
    Ok(c)
}

/// Tier churn (disk-spill tentpole): under random append / demote /
/// fault-in / clone / drop interleavings against a real on-disk store,
/// the per-tier ledger stays *exact* after every op — uniform block
/// geometry makes both tiers countable to the byte — every spilled
/// payload faults back bit-identical, and tearing every owner down
/// empties both tiers and releases every store claim.
#[test]
fn prop_tier_churn_keeps_ledger_exact_and_spill_bit_identical() {
    prop::check(8, |g| {
        let dir = TestDir::new("tier");
        let store = Arc::new(KvStore::open(dir.path()).map_err(|e| format!("open: {e:#}"))?);
        let rpb = 4usize;
        let pool = BlockPool::unbounded(rpb);
        pool.bind_store(Arc::clone(&store));
        let d = g.usize(1, 3);
        let nh = g.usize(1, 2);
        let bpb = block_bytes(rpb, d);
        let cfg = CompressionConfig {
            policy: PolicyKind::LagKv,
            sink: g.usize(0, 3),
            lag: [4usize, 8][g.usize(0, 1)],
            ratio: 0.5,
            ..Default::default()
        };
        let mut scorer = make_policy(cfg.policy, g.case as u64);
        let mut rng = Rng::seed_from(g.case as u64 + 201);
        let mut caches = vec![KvCache::new_in(pool.clone(), 1, nh, d)];
        for _ in 0..g.usize(25, 90) {
            match g.usize(0, 9) {
                0..=4 => {
                    let i = g.usize(0, caches.len() - 1);
                    fill_one(&mut caches[i], &mut rng);
                    maybe_compress(&mut caches[i], &cfg, scorer.as_mut())
                        .map_err(|e| format!("driver: {e:#}"))?;
                }
                5..=6 => {
                    // demote a sliver or everything; the call's own
                    // accounting must agree with the gauge deltas
                    let target = if g.bool() { usize::MAX } else { g.usize(1, 2 * bpb) };
                    let before = pool.stats();
                    let (nblocks, nbytes) = pool.spill(target);
                    let after = pool.stats();
                    if nbytes != nblocks * bpb {
                        return Err(format!(
                            "spill returned {nbytes} bytes for {nblocks} blocks of {bpb}"
                        ));
                    }
                    if after.spilled_blocks != before.spilled_blocks + nblocks
                        || after.spilled_bytes != before.spilled_bytes + nbytes
                    {
                        return Err("spilled gauges diverged from the spill return".into());
                    }
                    if after.resident_bytes() + nbytes != before.resident_bytes() {
                        return Err("demotion did not move bytes resident -> spilled".into());
                    }
                }
                7 => {
                    // promote: a full gather after demoting everything
                    // must reproduce the pre-spill contents bit for bit
                    let i = g.usize(0, caches.len() - 1);
                    if caches[i].frozen_blocks() > 0 {
                        let snap = tier_snap(&caches[i]);
                        pool.spill(usize::MAX);
                        if tier_snap(&caches[i]) != snap {
                            return Err("fault-in changed a spilled block's bytes".into());
                        }
                    }
                }
                8 => {
                    // detach-style clone: shares frozen blocks CoW, and a
                    // shared block still demotes/faults exactly once
                    if caches.len() < 4 {
                        let i = g.usize(0, caches.len() - 1);
                        let c = caches[i].clone();
                        caches.push(c);
                    }
                }
                _ => {
                    if caches.len() > 1 {
                        let i = g.usize(0, caches.len() - 1);
                        caches.swap_remove(i);
                    }
                }
            }
            // per-op tier reconciliation: every frozen block is full (rpb
            // rows at width d), so both tiers are exactly countable
            let s = pool.stats();
            if s.spilled_bytes != s.spilled_blocks * bpb {
                return Err(format!(
                    "spilled tier out of step: {} bytes vs {} blocks",
                    s.spilled_bytes, s.spilled_blocks
                ));
            }
            if s.block_bytes != s.resident_blocks * bpb {
                return Err(format!(
                    "resident tier out of step: {} bytes vs {} blocks",
                    s.block_bytes, s.resident_blocks
                ));
            }
            let owned: usize = caches.iter().map(|c| c.exact_bytes()).sum();
            let pooled = s.resident_bytes() + s.spilled_bytes;
            if pooled > owned {
                return Err(format!(
                    "both tiers together ({pooled}) exceed every owner's footprint ({owned})"
                ));
            }
            let biggest = caches.iter().map(|c| c.exact_bytes()).max().unwrap_or(0);
            if pooled < biggest {
                return Err(format!(
                    "tiers ({pooled}) lost bytes against a single cache's {biggest}"
                ));
            }
        }
        // deterministic round trip even when the walk never froze: grow
        // the first cache until it pages, demote everything, fault back
        for _ in 0..400 {
            if caches[0].frozen_blocks() > 0 {
                break;
            }
            fill_one(&mut caches[0], &mut rng);
            maybe_compress(&mut caches[0], &cfg, scorer.as_mut())
                .map_err(|e| format!("driver: {e:#}"))?;
        }
        if caches[0].frozen_blocks() == 0 {
            return Err("could not freeze a block in 400 appends".into());
        }
        let snap = tier_snap(&caches[0]);
        pool.spill(usize::MAX);
        let s = pool.stats();
        if s.resident_blocks != 0 {
            return Err(format!(
                "{} blocks stayed resident with no read guard held",
                s.resident_blocks
            ));
        }
        let total = s.spilled_blocks;
        if tier_snap(&caches[0]) != snap {
            return Err("spilled payloads are not bit-identical after fault-in".into());
        }
        let s = pool.stats();
        if s.resident_blocks + s.spilled_blocks != total {
            return Err("fault-in created or lost blocks".into());
        }
        // teardown: dropping every owner (spilled blocks included) must
        // empty both tiers and release every store claim
        caches.clear();
        let s = pool.stats();
        if s.resident_blocks != 0 || s.resident_bytes() != 0 {
            return Err(format!("resident tier leaked ({} blocks)", s.resident_blocks));
        }
        if s.spilled_blocks != 0 || s.spilled_bytes != 0 {
            return Err(format!("spilled tier leaked ({} blocks)", s.spilled_blocks));
        }
        let (_, _, blocks) = store.inventory_counts();
        if blocks != 0 {
            return Err(format!("{blocks} store records survive with no live claim"));
        }
        Ok(())
    });
}

/// Int8 decode error is bounded by half the per-row quantization step:
/// for every frozen row, `|decoded - original| <= scale/2` with
/// `scale = max|row| / 127` — while an fp32 layer of the *same* cache
/// (the codec map is per-layer) reads back bit-exact.  Pins the
/// encode-at-freeze / decode-at-read loop end to end across mixed row
/// magnitudes.
#[test]
fn prop_int8_decode_error_bounded_per_row_across_layers() {
    prop::check(8, |g| {
        let rpb = 4usize;
        let d = g.usize(2, 6);
        let nh = g.usize(1, 2);
        let pool = BlockPool::unbounded(rpb);
        let mut c = KvCache::new_in(pool, 2, nh, d);
        // layer 0 int8, layer 1 identity — exactly `--quant int8:0`
        c.set_quant(Arc::new(QuantSpec::parse("int8:0").map_err(|e| format!("parse: {e:#}"))?));
        let w = 2 * nh * d;
        let mut rng = Rng::seed_from(g.case as u64 + 77);
        let n = rpb * g.usize(2, 5);
        let mut rows_k: Vec<Vec<f32>> = Vec::new();
        let mut rows_v: Vec<Vec<f32>> = Vec::new();
        for t in 0..n {
            // wildly different row magnitudes: per-row scales must adapt
            let amp = [0.01f32, 1.0, 100.0][t % 3];
            let k: Vec<f32> = (0..w).map(|_| rng.normal() * amp).collect();
            let v: Vec<f32> = (0..w).map(|_| rng.normal() * amp).collect();
            c.append_token(&k, &v, t as i32).map_err(|e| format!("append: {e:#}"))?;
            rows_k.push(k);
            rows_v.push(v);
        }
        c.freeze_layer_prefix(0, n);
        c.freeze_layer_prefix(1, n);
        if c.frozen_rows(0) != n || c.frozen_rows(1) != n {
            return Err("block-aligned appends must freeze in full".into());
        }
        for layer in 0..2 {
            for h in 0..nh {
                let k = c.head_k(layer, h);
                let v = c.head_v(layer, h);
                let pos = c.positions(layer, h);
                if pos != (0..n as i32).collect::<Vec<_>>() {
                    return Err("positions must survive the codec exactly".into());
                }
                let off = (layer * nh + h) * d;
                for r in 0..n {
                    let orig_k = &rows_k[r][off..off + d];
                    let orig_v = &rows_v[r][off..off + d];
                    let dec_k = &k[r * d..(r + 1) * d];
                    let dec_v = &v[r * d..(r + 1) * d];
                    if layer == 1 {
                        if dec_k != orig_k || dec_v != orig_v {
                            return Err("fp32 layer must read back bit-exact".into());
                        }
                        continue;
                    }
                    for (orig, dec) in [(orig_k, dec_k), (orig_v, dec_v)] {
                        let max_abs = orig.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                        // half a quantization step, with fp headroom
                        let bound = max_abs / 127.0 * 0.501 + 1e-7;
                        for (o, x) in orig.iter().zip(dec) {
                            if (o - x).abs() > bound {
                                return Err(format!(
                                    "layer 0 row {r}: |{o} - {x}| exceeds half-step {bound}"
                                ));
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Quantized tier churn: with every freeze routed through the int8
/// codec, random append / demote / fault-in / clone / drop
/// interleavings keep the encoded ledger *exact* after every op —
/// `quant_bytes == quant_blocks * encoded_block_bytes`, the spilled
/// tier counts the same encoded units, no plain block ever appears, and
/// decode caches stay block-granular and bounded by encoded residency.
/// Teardown empties every gauge and store claim.
#[test]
fn prop_quant_churn_keeps_encoded_ledger_exact() {
    prop::check(8, |g| {
        let dir = TestDir::new("quant-churn");
        let store = Arc::new(KvStore::open(dir.path()).map_err(|e| format!("open: {e:#}"))?);
        let rpb = 4usize;
        let pool = BlockPool::unbounded(rpb);
        pool.bind_store(Arc::clone(&store));
        let d = g.usize(1, 3);
        let nh = g.usize(1, 2);
        let bpb = block_bytes(rpb, d);
        let enc_bpb = CodecKind::Int8Sym.encoded_block_bytes(rpb, d);
        let quant = Arc::new(QuantSpec::all(CodecKind::Int8Sym));
        let cfg = CompressionConfig {
            policy: PolicyKind::LagKv,
            sink: g.usize(0, 3),
            lag: [4usize, 8][g.usize(0, 1)],
            ratio: 0.5,
            ..Default::default()
        };
        let mut scorer = make_policy(cfg.policy, g.case as u64);
        let mut rng = Rng::seed_from(g.case as u64 + 977);
        let mut first = KvCache::new_in(pool.clone(), 1, nh, d);
        first.set_quant(Arc::clone(&quant));
        let mut caches = vec![first];
        for _ in 0..g.usize(25, 90) {
            match g.usize(0, 9) {
                0..=4 => {
                    let i = g.usize(0, caches.len() - 1);
                    fill_one(&mut caches[i], &mut rng);
                    maybe_compress(&mut caches[i], &cfg, scorer.as_mut())
                        .map_err(|e| format!("driver: {e:#}"))?;
                }
                5..=6 => {
                    let target = if g.bool() { usize::MAX } else { g.usize(1, 2 * enc_bpb) };
                    let before = pool.stats();
                    let (nblocks, nbytes) = pool.spill(target);
                    let after = pool.stats();
                    // every demoted block moves exactly its encoded bytes
                    // quant -> spilled ...
                    if after.spilled_blocks != before.spilled_blocks + nblocks
                        || after.spilled_bytes != before.spilled_bytes + nblocks * enc_bpb
                    {
                        return Err("spilled gauges diverged from encoded units".into());
                    }
                    if before.quant_blocks != after.quant_blocks + nblocks {
                        return Err("demotion did not drain the encoded tier".into());
                    }
                    // ... and the call's own return counts those encoded
                    // bytes plus any decode caches dropped alongside
                    let dq_dropped = before.dq_bytes - after.dq_bytes;
                    if nbytes != nblocks * enc_bpb + dq_dropped {
                        return Err(format!(
                            "spill returned {nbytes} bytes for {nblocks} encoded blocks \
                             of {enc_bpb} (+{dq_dropped} decode-cache)"
                        ));
                    }
                }
                7 => {
                    // promote: a full gather after demoting everything must
                    // reproduce the pre-spill decoded view exactly (same
                    // encoded bytes in, same deterministic decode out)
                    let i = g.usize(0, caches.len() - 1);
                    if caches[i].frozen_blocks() > 0 {
                        let snap = tier_snap(&caches[i]);
                        pool.spill(usize::MAX);
                        if tier_snap(&caches[i]) != snap {
                            return Err("fault-in changed a quantized block's decode".into());
                        }
                    }
                }
                8 => {
                    if caches.len() < 4 {
                        let i = g.usize(0, caches.len() - 1);
                        let c = caches[i].clone();
                        caches.push(c);
                    }
                }
                _ => {
                    if caches.len() > 1 {
                        let i = g.usize(0, caches.len() - 1);
                        caches.swap_remove(i);
                    }
                }
            }
            // per-op reconciliation: both tiers countable in exact encoded
            // units; the decode cache is block-granular fp32 copies of a
            // subset of the encoded-resident blocks
            let s = pool.stats();
            if s.quant_bytes != s.quant_blocks * enc_bpb {
                return Err(format!(
                    "encoded tier out of step: {} bytes vs {} blocks",
                    s.quant_bytes, s.quant_blocks
                ));
            }
            if s.spilled_bytes != s.spilled_blocks * enc_bpb {
                return Err(format!(
                    "spilled tier out of step: {} bytes vs {} blocks",
                    s.spilled_bytes, s.spilled_blocks
                ));
            }
            if s.block_bytes != 0 || s.resident_blocks != 0 {
                return Err("a plain block appeared under an all-int8 codec map".into());
            }
            if s.dq_bytes % bpb != 0 || s.dq_bytes > s.quant_blocks * bpb {
                return Err(format!(
                    "decode cache out of step: {} bytes with {} encoded blocks",
                    s.dq_bytes, s.quant_blocks
                ));
            }
            // conservation over *data* bytes (decode caches are redundant
            // copies, accounted separately): pooled never exceeds the sum
            // of every owner's exact footprint, never loses a cache's worth
            let owned: usize = caches.iter().map(|c| c.exact_bytes()).sum();
            let pooled = s.quant_bytes + s.loose_bytes + s.spilled_bytes;
            if pooled > owned {
                return Err(format!(
                    "encoded + loose + spilled ({pooled}) exceed every owner's \
                     footprint ({owned})"
                ));
            }
            let biggest = caches.iter().map(|c| c.exact_bytes()).max().unwrap_or(0);
            if pooled < biggest {
                return Err(format!(
                    "tiers ({pooled}) lost bytes against a single cache's {biggest}"
                ));
            }
        }
        // deterministic drain: grow until a block freezes, spill all —
        // the encoded tier and its decode caches must empty together
        for _ in 0..400 {
            if caches[0].frozen_blocks() > 0 {
                break;
            }
            fill_one(&mut caches[0], &mut rng);
            maybe_compress(&mut caches[0], &cfg, scorer.as_mut())
                .map_err(|e| format!("driver: {e:#}"))?;
        }
        if caches[0].frozen_blocks() == 0 {
            return Err("could not freeze a block in 400 appends".into());
        }
        let snap = tier_snap(&caches[0]);
        pool.spill(usize::MAX);
        let s = pool.stats();
        if s.quant_blocks != 0 || s.quant_bytes != 0 || s.dq_bytes != 0 {
            return Err("full spill must drain the encoded tier and its decode caches".into());
        }
        if tier_snap(&caches[0]) != snap {
            return Err("decoded view changed across an encoded spill→fault round trip".into());
        }
        // teardown: dropping every owner empties every quant gauge and
        // releases every store claim
        caches.clear();
        let s = pool.stats();
        if s.quant_bytes != 0 || s.quant_blocks != 0 || s.dq_bytes != 0 {
            return Err(format!("encoded tier leaked ({} blocks)", s.quant_blocks));
        }
        if s.spilled_blocks != 0 || s.spilled_bytes != 0 {
            return Err(format!("spilled tier leaked ({} blocks)", s.spilled_blocks));
        }
        let (_, _, blocks) = store.inventory_counts();
        if blocks != 0 {
            return Err(format!("{blocks} store records survive with no live claim"));
        }
        Ok(())
    });
}

/// The *encoded* payload is what spills: after a full demotion a
/// quantized block faults back with byte-identical `data` and `sidecar`
/// (never a decode-then-respill), and the fault gauges count the round
/// trip in exact encoded units.
#[test]
fn prop_quant_spill_faults_back_bit_identical_encoded() {
    prop::check(8, |g| {
        let dir = TestDir::new("quant-fault");
        let store = Arc::new(KvStore::open(dir.path()).map_err(|e| format!("open: {e:#}"))?);
        let rpb = [2usize, 4][g.usize(0, 1)];
        let pool = BlockPool::unbounded(rpb);
        pool.bind_store(Arc::clone(&store));
        let d = g.usize(1, 5);
        let enc_bpb = CodecKind::Int8Sym.encoded_block_bytes(rpb, d);
        let mut rng = Rng::seed_from(g.case as u64 + 577);
        let n = g.usize(2, 6);
        let mut blocks = Vec::new();
        for b in 0..n {
            let k: Vec<f32> = (0..rpb * d).map(|_| rng.normal()).collect();
            let v: Vec<f32> = (0..rpb * d).map(|_| rng.normal()).collect();
            let pos: Vec<i32> =
                (0..rpb as i32).map(|r| b as i32 * rpb as i32 + r).collect();
            let attn = vec![0.0f32; rpb];
            let blk = BlockPool::alloc_quant_block(
                &pool,
                d,
                CodecKind::Int8Sym,
                &k,
                &v,
                &pos,
                &attn,
                0,
            )
            .map_err(|e| format!("alloc: {e}"))?;
            blocks.push(blk);
        }
        let mut want: Vec<EncodedKv> = Vec::with_capacity(n);
        for b in &blocks {
            want.push(
                b.encoded().ok_or_else(|| "fresh block must be encoded-resident".to_string())?,
            );
        }
        let before = pool.stats();
        let (nb, _) = pool.spill(usize::MAX);
        if nb != n {
            return Err(format!("spill demoted {nb} of {n} blocks"));
        }
        for b in &blocks {
            if b.encoded().is_some() {
                return Err("a spilled block still holds its encoded payload".into());
            }
        }
        // fault back through the read path and compare the encoded form
        for (b, w) in blocks.iter().zip(&want) {
            let _ = b.read();
            match b.encoded() {
                Some(e) if e == *w => {}
                Some(_) => return Err("fault-in changed the encoded payload".into()),
                None => return Err("read did not fault the encoded payload back".into()),
            }
        }
        let after = pool.stats();
        if after.faults != before.faults + n as u64 {
            return Err("fault counter out of step with the round trip".into());
        }
        if after.fault_bytes != before.fault_bytes + n * enc_bpb {
            return Err("fault bytes not counted in encoded units".into());
        }
        Ok(())
    });
}

/// WAL tentpole: a random churn of session / prefix-snapshot journal
/// puts, removes, supersedes, and mid-run checkpoints — ending in a
/// crash (drop with no final cleanup) — replays to *exactly* the
/// surviving inventory: same ids, same counts, every restored cache
/// bit-identical to what was journaled, removes never resurrect (the
/// eviction no-resurrect fix), and restored blocks adopt spilled-first
/// (zero resident bytes until read).
#[test]
fn prop_wal_checkpoint_crash_replay_reproduces_inventory() {
    prop::check(6, |g| {
        let dir = TestDir::new("wal");
        let d = g.usize(1, 2);
        let cfg = CompressionConfig {
            policy: PolicyKind::LagKv,
            sink: g.usize(0, 2),
            lag: 4,
            ratio: 0.5,
            ..Default::default()
        };
        let mut scorer = make_policy(cfg.policy, g.case as u64);
        let mut rng = Rng::seed_from(g.case as u64 + 307);
        let mut want_sessions: HashMap<String, (usize, Vec<HeadSnap>)> = HashMap::new();
        let mut want_prefixes: HashMap<u64, (usize, Vec<HeadSnap>)> = HashMap::new();
        {
            let store =
                Arc::new(KvStore::open(dir.path()).map_err(|e| format!("open: {e:#}"))?);
            let pool = BlockPool::unbounded(4);
            pool.bind_store(Arc::clone(&store));
            // live handles persist alongside the journal, as in serving —
            // their claims must not keep records alive past the crash
            let mut live: Vec<KvCache> = Vec::new();
            for _ in 0..g.usize(10, 40) {
                match g.usize(0, 6) {
                    0..=2 => {
                        // journal a session; a small id space forces
                        // supersedes (old claims must release)
                        let n = g.usize(3, 30);
                        let c = grown_cache(&pool, d, n, &cfg, scorer.as_mut(), &mut rng)?;
                        let id = format!("s{}", g.usize(0, 4));
                        let desc = c.persist(&store).map_err(|e| format!("persist: {e:#}"))?;
                        store
                            .journal_session_put(&id, desc)
                            .map_err(|e| format!("sput: {e:#}"))?;
                        want_sessions.insert(id, (c.appended, tier_snap(&c)));
                        live.push(c);
                    }
                    3 => {
                        let id = format!("s{}", g.usize(0, 4));
                        let dropped = store
                            .journal_session_remove(&id)
                            .map_err(|e| format!("srem: {e:#}"))?;
                        if dropped != want_sessions.remove(&id).is_some() {
                            return Err(format!("remove of {id} disagrees with the mirror"));
                        }
                    }
                    4 => {
                        let n = g.usize(3, 30);
                        let c = grown_cache(&pool, d, n, &cfg, scorer.as_mut(), &mut rng)?;
                        let desc = c.persist(&store).map_err(|e| format!("persist: {e:#}"))?;
                        let pid = store
                            .journal_prefix_put(desc)
                            .map_err(|e| format!("pput: {e:#}"))?;
                        want_prefixes.insert(pid, (c.appended, tier_snap(&c)));
                        live.push(c);
                    }
                    5 => {
                        let next_pid = want_prefixes.keys().next().copied();
                        if let Some(pid) = next_pid {
                            if !store
                                .journal_prefix_remove(pid)
                                .map_err(|e| format!("prem: {e:#}"))?
                            {
                                return Err(format!("journaled prefix {pid} was not dropped"));
                            }
                            want_prefixes.remove(&pid);
                        }
                    }
                    _ => {
                        store.checkpoint().map_err(|e| format!("checkpoint: {e:#}"))?;
                    }
                }
                let (ns, np, _) = store.inventory_counts();
                if ns != want_sessions.len() || np != want_prefixes.len() {
                    return Err(format!(
                        "live inventory ({ns} sessions, {np} prefixes) drifted from the \
                         mirror ({}, {})",
                        want_sessions.len(),
                        want_prefixes.len()
                    ));
                }
            }
            store.checkpoint().map_err(|e| format!("checkpoint: {e:#}"))?;
            // a torn tail of pure removes after the last checkpoint must
            // still replay: evictions never resurrect
            if g.bool() {
                let victim = want_sessions.keys().next().cloned();
                if let Some(id) = victim {
                    store.journal_session_remove(&id).map_err(|e| format!("srem: {e:#}"))?;
                    want_sessions.remove(&id);
                }
            }
            // crash: the store and every live handle drop right here,
            // with no further checkpoint
        }
        let store = Arc::new(KvStore::open(dir.path()).map_err(|e| format!("reopen: {e:#}"))?);
        let (ns, np, _) = store.inventory_counts();
        if ns != want_sessions.len() || np != want_prefixes.len() {
            return Err(format!(
                "replay produced ({ns} sessions, {np} prefixes), expected ({}, {})",
                want_sessions.len(),
                want_prefixes.len()
            ));
        }
        let pool = BlockPool::unbounded(4);
        pool.bind_store(Arc::clone(&store));
        let mut handles = HashMap::new();
        for (id, desc) in store.boot_sessions() {
            let Some(want) = want_sessions.get(&id) else {
                return Err(format!("session {id} resurrected after removal"));
            };
            let resident_before = pool.stats().resident_blocks;
            let c = KvCache::restore(&pool, &store, &desc, &mut handles)
                .map_err(|e| format!("restore {id}: {e:#}"))?;
            if pool.stats().resident_blocks != resident_before {
                return Err("restore faulted blocks in before first read".into());
            }
            if c.appended != want.0 || tier_snap(&c) != want.1 {
                return Err(format!("session {id} did not restore bit-identically"));
            }
        }
        for (pid, desc) in store.boot_prefixes() {
            let Some(want) = want_prefixes.get(&pid) else {
                return Err(format!("prefix snapshot {pid} resurrected after removal"));
            };
            let c = KvCache::restore(&pool, &store, &desc, &mut handles)
                .map_err(|e| format!("restore prefix {pid}: {e:#}"))?;
            if c.appended != want.0 || tier_snap(&c) != want.1 {
                return Err(format!("prefix snapshot {pid} did not restore bit-identically"));
            }
        }
        Ok(())
    });
}

/// The paper's headline ordering as a standing regression: at equal
/// compression ratios (identical retained lengths, asserted), LagKV
/// retains strictly more ground-truth needle tokens than StreamingLLM-
/// style recency eviction — across every ratio in the paper's grid.
#[test]
fn sim_regression_lagkv_beats_recency_at_equal_ratios() {
    let spec = SimSpec::default();
    let seeds = 0..6u64;
    for &r in &[0.5, 0.25, 0.125] {
        let run = |policy: PolicyKind, seed: u64| {
            let cfg = CompressionConfig {
                policy,
                sink: 4,
                lag: 32,
                ratio: r,
                ..Default::default()
            };
            sim::run(&spec, &cfg, seed)
        };
        let mut lag_sum = 0.0;
        let mut st_sum = 0.0;
        for seed in seeds.clone() {
            let l = run(PolicyKind::LagKv, seed);
            let s = run(PolicyKind::Streaming, seed);
            assert_eq!(
                l.cache_len, s.cache_len,
                "policies must compress to identical lengths (fair comparison, r={r})"
            );
            lag_sum += l.needle_recall;
            st_sum += s.needle_recall;
        }
        let (lag, st) = (lag_sum / 6.0, st_sum / 6.0);
        assert!(
            lag > st + 0.2,
            "r={r}: lagkv needle recall {lag:.3} must clearly beat recency {st:.3}"
        );
    }
}

/// The same standing regression against StreamingLLM *proper* (global
/// sink+recency, not the per-partition recency baseline above): what
/// survives StreamingLLM is exactly the attention sink plus the newest
/// window, so a mid-context needle is gone by construction while LagKV
/// keeps most of it.  The global-scope driver path shares the partition
/// path's eviction budget and trigger cadence, so the retained lengths
/// are identical — asserted, to keep the comparison fair.
#[test]
fn sim_regression_lagkv_beats_streamingllm_at_equal_ratios() {
    let spec = SimSpec::default();
    let seeds = 0..6u64;
    for &r in &[0.5, 0.25, 0.125] {
        let run = |policy: PolicyKind, seed: u64| {
            let cfg = CompressionConfig {
                policy,
                sink: 4,
                lag: 32,
                ratio: r,
                ..Default::default()
            };
            sim::run(&spec, &cfg, seed)
        };
        let mut lag_sum = 0.0;
        let mut sl_sum = 0.0;
        for seed in seeds.clone() {
            let l = run(PolicyKind::LagKv, seed);
            let s = run(PolicyKind::StreamingLlm, seed);
            assert_eq!(
                l.cache_len, s.cache_len,
                "policies must compress to identical lengths (fair comparison, r={r})"
            );
            lag_sum += l.needle_recall;
            sl_sum += s.needle_recall;
        }
        let (lag, sl) = (lag_sum / 6.0, sl_sum / 6.0);
        assert!(
            lag > sl + 0.2,
            "r={r}: lagkv needle recall {lag:.3} must clearly beat streamingllm {sl:.3}"
        );
    }
}

/// Quantization must not reorder the paper's headline result: with every
/// block frozen through the int8 codec, the driver scores over *decoded*
/// (lossy) rows — and at r=0.5 LagKV still clearly beats recency eviction,
/// with cache lengths unchanged by the codec (Eq. 10 is byte-layout
/// independent).
#[test]
fn sim_regression_int8_blocks_preserve_lagkv_ordering() {
    let fp_spec = SimSpec::default();
    let q_spec = SimSpec {
        quant: QuantSpec::all(CodecKind::Int8Sym),
        ..Default::default()
    };
    let run = |spec: &SimSpec, policy: PolicyKind, seed: u64| {
        let cfg = CompressionConfig {
            policy,
            sink: 4,
            lag: 32,
            ratio: 0.5,
            ..Default::default()
        };
        sim::run(spec, &cfg, seed)
    };
    let mut lag_sum = 0.0;
    let mut st_sum = 0.0;
    for seed in 0..6u64 {
        let l = run(&q_spec, PolicyKind::LagKv, seed);
        let s = run(&q_spec, PolicyKind::Streaming, seed);
        assert_eq!(
            l.cache_len, s.cache_len,
            "int8 runs must compress to identical lengths (fair comparison)"
        );
        // the codec changes bytes, never retention arithmetic
        let fp = run(&fp_spec, PolicyKind::LagKv, seed);
        assert_eq!(l.cache_len, fp.cache_len, "codec must not change Eq. 10");
        lag_sum += l.needle_recall;
        st_sum += s.needle_recall;
    }
    let (lag, st) = (lag_sum / 6.0, st_sum / 6.0);
    assert!(
        lag > st + 0.2,
        "int8 r=0.5: lagkv needle recall {lag:.3} must clearly beat recency {st:.3}"
    );
}

/// Compression monotonicity on the simulator: more aggressive ratios never
/// retain more needle tokens (averaged over seeds).
#[test]
fn sim_recall_monotone_in_ratio() {
    let spec = SimSpec::default();
    let mean = |r: f64| -> f64 {
        (0..5u64)
            .map(|s| {
                let cfg = CompressionConfig {
                    policy: PolicyKind::LagKv,
                    sink: 4,
                    lag: 32,
                    ratio: r,
                    ..Default::default()
                };
                sim::run(&spec, &cfg, s).needle_recall
            })
            .sum::<f64>()
            / 5.0
    };
    let r2 = mean(0.5);
    let r4 = mean(0.25);
    let r8 = mean(0.125);
    assert!(r2 >= r4 - 1e-9, "2x {r2:.3} < 4x {r4:.3}");
    assert!(r4 >= r8 - 1e-9, "4x {r4:.3} < 8x {r8:.3}");
}

//! Hermetic static-analysis self-check: the real tree must lint clean
//! against the checked-in baseline, and the baseline must never
//! grandfather anything in the swept layers.  Runs under plain
//! `cargo test -q` — same contract as CI's dedicated lint step
//! (`cargo run -p lagkv-lint -- check`).

use std::path::Path;

use lagkv_lint::baseline::Baseline;
use lagkv_lint::{check_tree, Rule};

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn load_baseline() -> Baseline {
    let path = repo_root().join("tools").join("lagkv-lint").join("baseline.txt");
    Baseline::load(&path).expect("baseline parses")
}

#[test]
fn real_tree_lints_clean_with_baseline() {
    let vios = check_tree(repo_root()).expect("tree scans");
    let (remaining, _grandfathered) = load_baseline().apply(vios);
    let report: Vec<String> = remaining.iter().map(|v| v.to_string()).collect();
    assert!(
        remaining.is_empty(),
        "lagkv-lint violations (fix, or add `// lint: allow(<rule>): <reason>`):\n{}",
        report.join("\n")
    );
}

#[test]
fn baseline_grandfathers_only_panics_outside_the_swept_layers() {
    for (rule, path, count) in load_baseline().entries() {
        assert_eq!(
            *rule,
            Rule::Panic,
            "only pre-existing panic sites may be grandfathered; {path} grandfathers {rule}"
        );
        assert!(*count > 0, "dead baseline entry for {path}");
        for swept in
            ["rust/src/server/", "rust/src/coordinator/", "rust/src/api/", "rust/src/telemetry/"]
        {
            assert!(
                !path.starts_with(swept),
                "{path}: the swept layers carry no baseline — use typed errors or an allow comment"
            );
        }
    }
}

#[test]
fn baseline_counts_are_not_stale() {
    // Every entry's budget must be fully consumed: a lowered real count
    // means the baseline should shrink with it (ratchet, not cushion).
    let vios = check_tree(repo_root()).expect("tree scans");
    for (rule, path, count) in load_baseline().entries() {
        let found = vios.iter().filter(|v| v.rule == *rule && &v.file == path).count();
        assert!(
            found >= *count,
            "baseline grants {count} `{rule}` in {path} but only {found} exist — lower the entry"
        );
    }
}

//! Hermetic end-to-end tests on the CPU reference backend: generation,
//! recursive compression cadence, continuous batching, and the in-proc
//! router all run under plain `cargo test` — no artifacts, no XLA, no
//! network.  This is the standing quality gate the PJRT integration tests
//! (rust/tests/integration.rs) extend when artifacts exist.

use lagkv::backend::EngineSpec;
use lagkv::config::{CompressionConfig, PolicyKind, ScorerBackend};
use lagkv::coordinator::{Request, Router};
use lagkv::engine::Engine;
use lagkv::kvcache::ratio;
use lagkv::util::rng::Rng;
use lagkv::workloads::passkey::{gen_passkey, PasskeySpec};

fn engine() -> Engine {
    Engine::cpu_ref("llama_like").unwrap()
}

#[test]
fn cpu_engine_reports_consistent_dims() {
    let e = engine();
    assert_eq!(e.backend().kind(), "cpu-ref");
    assert_eq!(e.dims.vocab_size, e.tokenizer.vocab.size());
    assert!(e.dims.n_layers >= 2);
    assert_eq!(e.dims.n_q_heads % e.dims.n_kv_heads, 0);
    assert!(e.decode_buckets().contains(&1));
    assert!(e.tmax >= 512);
}

#[test]
fn generation_is_deterministic_and_nonempty() {
    let e = engine();
    let mut rng = Rng::seed_from(3);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 120, n_digits: 16, depth: None });
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        sink: 4,
        lag: 16,
        ratio: 0.5,
        ..Default::default()
    };
    let a = e.generate(&item.prompt, &cfg, 12, 0).unwrap();
    let b = e.generate(&item.prompt, &cfg, 12, 0).unwrap();
    assert!(!a.tokens.is_empty());
    assert!(a.prompt_tokens > 100);
    assert_eq!(a.tokens, b.tokens, "same prompt+seed must decode identically");
    assert_eq!(a.text, b.text);
    assert_eq!(a.cache_lens, b.cache_lens);
}

#[test]
fn generation_cache_length_matches_eq10_on_cpu_backend() {
    let e = engine();
    let mut rng = Rng::seed_from(11);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 200, n_digits: 16, depth: None });
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        sink: 4,
        lag: 16,
        ratio: 0.25,
        ..Default::default()
    };
    let out = e.generate(&item.prompt, &cfg, 8, 0).unwrap();
    assert!(!out.tokens.is_empty());
    // the last generated token is returned but never appended (no decode
    // step consumed it), so the cache holds total-1 rows
    let total = out.prompt_tokens + out.tokens.len() - 1;
    let want = ratio::retained_len(total, cfg.sink, cfg.lag, cfg.keep_per_partition());
    for (layer, &len) in out.cache_lens.iter().enumerate() {
        assert_eq!(len, want, "layer {layer}: cache len {len} != Eq.10 {want} (total {total})");
    }
    assert!(out.compression_events > 0, "compression must have fired");
    // baseline for the same prompt is strictly larger
    let base = CompressionConfig { policy: PolicyKind::None, ..Default::default() };
    let b = e.generate(&item.prompt, &base, 8, 0).unwrap();
    assert!(out.cache_lens[0] < b.cache_lens[0]);
}

#[test]
fn every_policy_generates_on_cpu_backend() {
    let e = engine();
    let mut rng = Rng::seed_from(12);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 120, n_digits: 8, depth: None });
    for &policy in PolicyKind::all() {
        let cfg = CompressionConfig {
            policy,
            sink: 4,
            lag: 16,
            ratio: 0.5,
            skip_layers: if policy == PolicyKind::L2Norm { 1 } else { 0 },
            ..Default::default()
        };
        let out = e.generate(&item.prompt, &cfg, 6, 0).unwrap();
        assert!(!out.tokens.is_empty(), "{} generated nothing", policy.name());
        if policy == PolicyKind::L2Norm {
            // the skipped layer stays uncompressed -> at least as long
            assert!(out.cache_lens[0] >= out.cache_lens[e.dims.n_layers - 1]);
        }
    }
}

#[test]
fn xla_scorer_request_falls_back_to_rust_on_cpu_backend() {
    let e = engine();
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        scorer: ScorerBackend::Xla,
        ..Default::default()
    };
    let scorer = e.make_scorer(&cfg, 0);
    assert_eq!(scorer.name(), "lagkv", "cpu backend must fall back to the rust scorer");
}

#[test]
fn overlong_prompt_is_a_clean_error() {
    let e = engine();
    let prompt = "the of and to in is it on as with ".repeat(80); // >> 640 tokens
    let cfg = CompressionConfig::default();
    let err = e.generate(&prompt, &cfg, 4, 0);
    assert!(err.is_err(), "overlong prompt must not panic");
}

#[test]
fn batched_decode_matches_single_on_cpu_backend() {
    // The same prompt decoded alone (bucket 1 via generate) and inside a
    // shared batch must produce identical tokens (slot independence).
    let e = engine();
    assert!(e.decode_buckets().contains(&4));
    let mut rng = Rng::seed_from(14);
    let prompts: Vec<String> = (0..2)
        .map(|_| {
            gen_passkey(&mut rng, &PasskeySpec { n_filler: 60, n_digits: 6, depth: None }).prompt
        })
        .collect();
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        lag: 16,
        ratio: 0.5,
        sink: 4,
        ..Default::default()
    };

    let solo: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| e.generate(p, &cfg, 5, 0).unwrap().tokens)
        .collect();

    // batch: 2 occupied + 2 idle slots
    use lagkv::engine::SlotState;
    use lagkv::util::argmax;
    let mut slots: Vec<SlotState> = Vec::new();
    for p in &prompts {
        let ids = e.tokenizer.encode(p, true);
        let (logits, cache) = e.prefill(&ids).unwrap();
        let first = argmax(&logits) as i32;
        let scorer = e.make_scorer(&cfg, 0);
        let mut slot = SlotState::occupied(cache, cfg.clone(), scorer, first, 5);
        if let Some(seq) = slot.active_mut() {
            let ev = lagkv::compress::maybe_compress(&mut seq.cache, &cfg, seq.scorer.as_mut())
                .unwrap();
            seq.compression_events += ev.len();
            seq.push_generated(first, e.tmax);
        }
        slots.push(slot);
    }
    slots.push(SlotState::idle());
    slots.push(SlotState::idle());
    while slots.iter().any(|s| s.active().is_some()) {
        e.step_batch(&mut slots).unwrap();
    }
    for (i, want) in solo.iter().enumerate() {
        let got = slots[i].take().unwrap().generated;
        assert_eq!(&got, want, "slot {i} diverged from solo decode");
    }
}

#[test]
fn router_round_trip_on_cpu_backend() {
    let router = Router::start(EngineSpec::cpu(), &["llama_like".to_string()]);
    let mut rng = Rng::seed_from(21);
    for (id, policy) in [(1u64, PolicyKind::LagKv), (2, PolicyKind::None), (3, PolicyKind::H2O)] {
        let item =
            gen_passkey(&mut rng, &PasskeySpec { n_filler: 80, n_digits: 8, depth: None });
        let resp = router
            .generate(
                "llama_like",
                Request {
                    id,
                    prompt: item.prompt.clone(),
                    compression: CompressionConfig {
                        policy,
                        sink: 4,
                        lag: 16,
                        ratio: 0.5,
                        ..Default::default()
                    },
                    max_new: 6,
                    seed: 0,
                },
            )
            .unwrap();
        assert_eq!(resp.id, id);
        assert!(resp.error.is_none(), "policy {}: {:?}", policy.name(), resp.error);
        assert!(!resp.tokens.is_empty());
        assert!(resp.prompt_tokens > 0);
        assert!(!resp.cache_lens.is_empty());
    }
    // unknown model is an error, not a hang
    let bad = router.generate(
        "missing_model",
        Request {
            id: 9,
            prompt: "x".into(),
            compression: CompressionConfig::default(),
            max_new: 1,
            seed: 0,
        },
    );
    assert!(bad.is_err());
    router.shutdown();
}

#[test]
fn unknown_variant_engine_answers_requests_with_errors() {
    // A variant that fails to load must answer queued requests with an
    // error response instead of dropping them (router resilience).
    let router = Router::start(EngineSpec::cpu(), &["not_a_model".to_string()]);
    let resp = router
        .generate(
            "not_a_model",
            Request {
                id: 5,
                prompt: "hello there".into(),
                compression: CompressionConfig::default(),
                max_new: 2,
                seed: 0,
            },
        )
        .unwrap();
    assert_eq!(resp.id, 5);
    assert!(resp.error.is_some());
    router.shutdown();
}

#[test]
fn harness_sim_table_renders() {
    let t = lagkv::harness::sim_fig5(2);
    let rendered = t.render();
    assert!(rendered.contains("lagkv"));
    assert!(rendered.contains("streaming"));
}

//! Hermetic end-to-end tests on the CPU reference backend: generation,
//! recursive compression cadence, continuous batching, the in-proc router
//! (event streams, cancellation, bounded queue), and the TCP server
//! (streaming NDJSON, multi-turn sessions, the v1 ops control plane) all
//! run under plain `cargo test` — no artifacts, no XLA, no network beyond
//! loopback.  All TCP traffic goes through the typed `lagkv::client` SDK;
//! the single hand-written JSON line below is the designated legacy
//! compat-shim probe.  This is the standing quality gate the PJRT
//! integration tests (rust/tests/integration.rs) extend when artifacts
//! exist.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use lagkv::backend::EngineSpec;
use lagkv::client::{Client, StreamItem};
use lagkv::config::{CompressionConfig, PolicyKind, ScorerBackend};
use lagkv::coordinator::{Event, GenerateParams, Router, RouterConfig, SessionConfig};
use lagkv::engine::Engine;
use lagkv::kvcache::ratio;
use lagkv::server::Server;
use lagkv::util::rng::Rng;
use lagkv::workloads::passkey::{gen_passkey, PasskeySpec};

fn engine() -> Engine {
    Engine::cpu_ref("llama_like").unwrap()
}

/// Boot the full TCP stack on an ephemeral port; returns (server, port,
/// stop flag, accept-thread handle).
fn boot_server() -> (
    Arc<Server>,
    u16,
    Arc<AtomicBool>,
    std::thread::JoinHandle<anyhow::Result<()>>,
) {
    let router = Arc::new(Router::start(EngineSpec::cpu(), &["llama_like".to_string()]));
    let server = Arc::new(Server::new(router));
    let stop = Arc::new(AtomicBool::new(false));
    let (listener, port) = Server::bind(0).unwrap();
    let handle = {
        let server = server.clone();
        let stop = stop.clone();
        std::thread::spawn(move || server.serve_listener(listener, stop))
    };
    (server, port, stop, handle)
}

/// A prompt whose greedy chain runs at least `min_tokens` before the toy
/// LM head emits EOS (the chain is a pure function of (token, pos), so a
/// scan is deterministic and policy-independent).
fn long_chain_prompt(e: &Engine, min_tokens: usize) -> String {
    let none = CompressionConfig { policy: PolicyKind::None, ..Default::default() };
    for seed in 0..400u64 {
        let mut rng = Rng::seed_from(seed);
        let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 20, n_digits: 8, depth: None });
        let out = e.generate(&item.prompt, &none, 600, 0).unwrap();
        if out.tokens.len() >= min_tokens {
            return item.prompt;
        }
    }
    panic!("no prompt with a >={min_tokens}-token greedy chain in 400 candidates");
}

#[test]
fn cpu_engine_reports_consistent_dims() {
    let e = engine();
    assert_eq!(e.backend().kind(), "cpu-ref");
    assert_eq!(e.dims.vocab_size, e.tokenizer.vocab.size());
    assert!(e.dims.n_layers >= 2);
    assert_eq!(e.dims.n_q_heads % e.dims.n_kv_heads, 0);
    assert!(e.decode_buckets().contains(&1));
    assert!(e.tmax >= 512);
}

#[test]
fn generation_is_deterministic_and_nonempty() {
    let e = engine();
    let mut rng = Rng::seed_from(3);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 120, n_digits: 16, depth: None });
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        sink: 4,
        lag: 16,
        ratio: 0.5,
        ..Default::default()
    };
    let a = e.generate(&item.prompt, &cfg, 12, 0).unwrap();
    let b = e.generate(&item.prompt, &cfg, 12, 0).unwrap();
    assert!(!a.tokens.is_empty());
    assert!(a.prompt_tokens > 100);
    assert_eq!(a.tokens, b.tokens, "same prompt+seed must decode identically");
    assert_eq!(a.text, b.text);
    assert_eq!(a.cache_lens, b.cache_lens);
}

#[test]
fn generation_cache_length_matches_eq10_on_cpu_backend() {
    let e = engine();
    let mut rng = Rng::seed_from(11);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 200, n_digits: 16, depth: None });
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        sink: 4,
        lag: 16,
        ratio: 0.25,
        ..Default::default()
    };
    let out = e.generate(&item.prompt, &cfg, 8, 0).unwrap();
    assert!(!out.tokens.is_empty());
    // the last generated token is returned but never appended (no decode
    // step consumed it), so the cache holds total-1 rows
    let total = out.prompt_tokens + out.tokens.len() - 1;
    let want = ratio::retained_len(total, cfg.sink, cfg.lag, cfg.keep_per_partition());
    for (layer, &len) in out.cache_lens.iter().enumerate() {
        assert_eq!(len, want, "layer {layer}: cache len {len} != Eq.10 {want} (total {total})");
    }
    assert!(out.compression_events > 0, "compression must have fired");
    // baseline for the same prompt is strictly larger
    let base = CompressionConfig { policy: PolicyKind::None, ..Default::default() };
    let b = e.generate(&item.prompt, &base, 8, 0).unwrap();
    assert!(out.cache_lens[0] < b.cache_lens[0]);
}

#[test]
fn every_policy_generates_on_cpu_backend() {
    let e = engine();
    let mut rng = Rng::seed_from(12);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 120, n_digits: 8, depth: None });
    for &policy in PolicyKind::all() {
        let cfg = CompressionConfig {
            policy,
            sink: 4,
            lag: 16,
            ratio: 0.5,
            skip_layers: if policy == PolicyKind::L2Norm { 1 } else { 0 },
            ..Default::default()
        };
        let out = e.generate(&item.prompt, &cfg, 6, 0).unwrap();
        assert!(!out.tokens.is_empty(), "{} generated nothing", policy.name());
        if policy == PolicyKind::L2Norm {
            // the skipped layer stays uncompressed -> at least as long
            assert!(out.cache_lens[0] >= out.cache_lens[e.dims.n_layers - 1]);
        }
    }
}

#[test]
fn xla_scorer_request_falls_back_to_rust_on_cpu_backend() {
    let e = engine();
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        scorer: ScorerBackend::Xla,
        ..Default::default()
    };
    let scorer = e.make_scorer(&cfg, 0);
    assert_eq!(scorer.name(), "lagkv", "cpu backend must fall back to the rust scorer");
}

#[test]
fn overlong_prompt_is_a_clean_error() {
    let e = engine();
    let prompt = "the of and to in is it on as with ".repeat(80); // >> 640 tokens
    let cfg = CompressionConfig::default();
    let err = e.generate(&prompt, &cfg, 4, 0);
    assert!(err.is_err(), "overlong prompt must not panic");
}

#[test]
fn batched_decode_matches_single_on_cpu_backend() {
    // The same prompt decoded alone (bucket 1 via generate) and inside a
    // shared batch must produce identical tokens (slot independence).
    let e = engine();
    assert!(e.decode_buckets().contains(&4));
    let mut rng = Rng::seed_from(14);
    let prompts: Vec<String> = (0..2)
        .map(|_| {
            gen_passkey(&mut rng, &PasskeySpec { n_filler: 60, n_digits: 6, depth: None }).prompt
        })
        .collect();
    let cfg = CompressionConfig {
        policy: PolicyKind::LagKv,
        lag: 16,
        ratio: 0.5,
        sink: 4,
        ..Default::default()
    };

    let solo: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| e.generate(p, &cfg, 5, 0).unwrap().tokens)
        .collect();

    // batch: 2 occupied + 2 idle slots
    use lagkv::engine::SlotState;
    use lagkv::util::argmax;
    let mut slots: Vec<SlotState> = Vec::new();
    for p in &prompts {
        let ids = e.tokenizer.encode(p, true);
        let (logits, cache) = e.prefill(&ids).unwrap();
        let first = argmax(&logits) as i32;
        let scorer = e.make_scorer(&cfg, 0);
        let mut slot = SlotState::occupied(cache, cfg.clone(), scorer, first, 5);
        if let Some(seq) = slot.active_mut() {
            let ev = lagkv::compress::maybe_compress(&mut seq.cache, &cfg, seq.scorer.as_mut())
                .unwrap();
            seq.compression_events += ev.len();
            seq.push_generated(first, e.tmax);
        }
        slots.push(slot);
    }
    slots.push(SlotState::idle());
    slots.push(SlotState::idle());
    while slots.iter().any(|s| s.active().is_some()) {
        e.step_batch(&mut slots).unwrap();
    }
    for (i, want) in solo.iter().enumerate() {
        let got = slots[i].take().unwrap().generated;
        assert_eq!(&got, want, "slot {i} diverged from solo decode");
    }
}

#[test]
fn router_round_trip_on_cpu_backend() {
    let router = Router::start(EngineSpec::cpu(), &["llama_like".to_string()]);
    let mut rng = Rng::seed_from(21);
    for (id, policy) in [(1u64, PolicyKind::LagKv), (2, PolicyKind::None), (3, PolicyKind::H2O)] {
        let item =
            gen_passkey(&mut rng, &PasskeySpec { n_filler: 80, n_digits: 8, depth: None });
        let resp = router
            .generate(
                "llama_like",
                GenerateParams::new(item.prompt.clone())
                    .policy(policy)
                    .sink(4)
                    .lag(16)
                    .ratio(0.5)
                    .max_new(6)
                    .into_request(id)
                    .unwrap(),
            )
            .unwrap();
        assert_eq!(resp.id, id);
        assert!(resp.error.is_none(), "policy {}: {:?}", policy.name(), resp.error);
        assert!(!resp.tokens.is_empty());
        assert!(resp.prompt_tokens > 0);
        assert!(!resp.cache_lens.is_empty());
    }
    // unknown model is a typed error, not a hang
    let bad = router.submit(
        "missing_model",
        GenerateParams::new("x").max_new(1).into_request(9).unwrap(),
    );
    assert_eq!(bad.err().map(|e| e.code()), Some("unknown-model"));
    router.shutdown();
}

#[test]
fn unknown_variant_engine_answers_requests_with_errors() {
    // A variant that fails to load must answer queued requests with an
    // error response instead of dropping them (router resilience).
    let router = Router::start(EngineSpec::cpu(), &["not_a_model".to_string()]);
    let resp = router
        .generate(
            "not_a_model",
            GenerateParams::new("hello there").max_new(2).into_request(5).unwrap(),
        )
        .unwrap();
    assert_eq!(resp.id, 5);
    assert_eq!(resp.error.as_ref().map(|e| e.code()), Some("engine-failure"));
    router.shutdown();
}

#[test]
fn harness_sim_table_renders() {
    let t = lagkv::harness::sim_fig5(2);
    let rendered = t.render();
    assert!(rendered.contains("lagkv"));
    assert!(rendered.contains("streaming"));
}

/// The acceptance scenario: a two-turn session over the TCP server reuses
/// the compressed cache.  Turn 2 prefills only its own text, and both the
/// decoded tokens and the Eq. 10 cache-length trajectory match a single
/// one-shot generation over the concatenated conversation.
#[test]
fn tcp_session_matches_concatenated_one_shot() {
    let (_server, port, stop, accept) = boot_server();
    let mut client = Client::connect(port).unwrap();

    let mut rng = Rng::seed_from(31);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 120, n_digits: 16, depth: None });
    let turn1 = item.prompt;
    let turn2 = "<q> the pass key <a>";
    let mk = |prompt: &str| {
        GenerateParams::new(prompt).lag(16).ratio(0.25).max_new(8).session("chat-parity")
    };
    let t1 = client.generate(Some(1), mk(&turn1)).unwrap();
    let t2 = client.generate(Some(2), mk(turn2)).unwrap();
    for t in [&t1, &t2] {
        assert!(t.error.is_none(), "turn failed: {t:?}");
    }

    let e = engine();
    let ids1 = e.tokenizer.encode(&turn1, true);
    let ids2 = e.tokenizer.encode(turn2, false);
    // Turn 2 prefills only the new text (the reattached history is
    // accounted separately), and reuses the whole turn-1 conversation.
    assert_eq!(t2.prompt_tokens, ids2.len());
    assert_eq!(t1.prompt_tokens, ids1.len());
    assert_eq!(
        t2.reused_tokens,
        ids1.len() + t1.tokens.len() - 1,
        "turn 2 must reuse every token turn 1 appended"
    );

    // The equivalent single prompt: turn-1 prompt ++ turn-1 reply ++ turn-2
    // text, prefilled from scratch.
    let mut concat = ids1.clone();
    concat.extend_from_slice(&t1.tokens);
    concat.extend_from_slice(&ids2);
    let cfg = GenerateParams::new("x").lag(16).ratio(0.25).compression();
    let solo = e.generate_ids(&concat, &cfg, 8, 0).unwrap();

    assert_eq!(t2.tokens, solo.tokens, "turn-2 decode must equal the concatenated one-shot");

    // Eq. 10 trajectory continues across the turn boundary: the session
    // cache ends at exactly the closed-form length for the *whole*
    // conversation (the last generated token is never appended).
    assert_eq!(t2.cache_lens, solo.cache_lens);
    let total = concat.len() + solo.tokens.len() - 1;
    let want = ratio::retained_len(total, cfg.sink, cfg.lag, cfg.keep_per_partition());
    for (layer, &len) in t2.cache_lens.iter().enumerate() {
        assert_eq!(len, want, "layer {layer}: session cache off the Eq. 10 trajectory");
    }
    // and strictly fewer tokens were prefilled on turn 2 than a
    // from-scratch turn would have needed
    assert!(ids2.len() < concat.len());

    stop.store(true, Ordering::Relaxed);
    accept.join().unwrap().unwrap();
}

/// Stream/one-shot parity through the typed client SDK: the folded stream
/// ([`lagkv::client::GenStream::wait`]) and the one-shot call describe the
/// same generation, field for field, and the raw typed events agree with
/// the one-shot counters.
#[test]
fn tcp_streaming_events_match_one_shot_through_client() {
    let (_server, port, stop, accept) = boot_server();
    let mut client = Client::connect(port).unwrap();
    let mut rng = Rng::seed_from(8);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 100, n_digits: 8, depth: None });
    let params = GenerateParams::new(item.prompt).lag(16).ratio(0.5).max_new(10);

    let mut stream = client.generate_stream(1, params.clone()).unwrap();
    let mut events = Vec::new();
    while let Some(item) = stream.next().unwrap() {
        if let StreamItem::Event(ev) = item {
            events.push(ev);
        }
    }
    let one_shot = client.generate(Some(2), params.clone()).unwrap();
    assert!(one_shot.error.is_none(), "{one_shot:?}");

    assert!(matches!(events.first(), Some(Event::Started { .. })), "events: {events:?}");
    let text: String = events
        .iter()
        .filter_map(|ev| match ev {
            Event::Token { text_delta, .. } => Some(text_delta.as_str()),
            _ => None,
        })
        .collect();
    assert_eq!(text, one_shot.text, "delta concat must equal the one-shot text");
    let n_compress =
        events.iter().filter(|ev| matches!(ev, Event::Compression { .. })).count();
    assert_eq!(
        n_compress, one_shot.compression_events,
        "one compression event line per driver event"
    );
    match events.last() {
        Some(Event::Done { usage, .. }) => {
            assert_eq!(usage.cache_lens, one_shot.cache_lens);
            assert_eq!(usage.new_tokens, one_shot.tokens.len());
        }
        other => panic!("stream must end with done, got {other:?}"),
    }

    // and the SDK's own fold agrees with the one-shot response wholesale
    let folded = client.generate_stream(3, params).unwrap().wait().unwrap();
    assert!(folded.error.is_none(), "{folded:?}");
    assert_eq!(folded.text, one_shot.text);
    assert_eq!(folded.tokens, one_shot.tokens);
    assert_eq!(folded.prompt_tokens, one_shot.prompt_tokens);
    assert_eq!(folded.cache_lens, one_shot.cache_lens);
    assert_eq!(folded.compression_events, one_shot.compression_events);

    stop.store(true, Ordering::Relaxed);
    accept.join().unwrap().unwrap();
}

/// Dropping the in-proc event handle aborts the slot mid-decode (the
/// drop-based cancellation path).
#[test]
fn dropping_the_handle_aborts_the_slot() {
    let e = engine();
    let prompt = long_chain_prompt(&e, 64);
    let router = Router::start(EngineSpec::cpu(), &["llama_like".to_string()]);
    let handle = router
        .submit(
            "llama_like",
            GenerateParams::new(prompt).max_new(600).into_request(10).unwrap(),
        )
        .unwrap();
    let first = handle.events.recv().unwrap();
    assert!(matches!(first, Event::Started { .. }), "got {first:?}");
    drop(handle);

    let stats = router.stats("llama_like").unwrap();
    let mut aborted = false;
    for _ in 0..500 {
        if stats.cancelled.load(Ordering::Relaxed) == 1 {
            aborted = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(aborted, "dropped handle must abort the slot");
    assert_eq!(stats.completed.load(Ordering::Relaxed), 0);
    router.shutdown();
}

/// Explicit cancellation folds to a typed `cancelled` error with fewer
/// tokens than the budget.
#[test]
fn explicit_cancel_terminates_with_typed_error() {
    let e = engine();
    let prompt = long_chain_prompt(&e, 64);
    let router = Router::start(EngineSpec::cpu(), &["llama_like".to_string()]);
    let handle = router
        .submit(
            "llama_like",
            GenerateParams::new(prompt).max_new(600).into_request(11).unwrap(),
        )
        .unwrap();
    let first = handle.events.recv().unwrap();
    assert!(matches!(first, Event::Started { .. }));
    handle.cancel();
    let resp = handle.wait();
    assert_eq!(resp.error.as_ref().map(|er| er.code()), Some("cancelled"));
    assert!(resp.tokens.len() < 600, "cancel must land mid-decode");
    router.shutdown();
}

/// Memory-pressure admission on a byte-budgeted pool: a resident session
/// is shed (LRU) to admit new work, an oversized request is a typed
/// `pool-exhausted` error, and the pool keeps serving afterwards.
#[test]
fn pool_pressure_sheds_sessions_and_rejects_typed() {
    let e = engine();
    let row = lagkv::kvpool::row_bytes(e.dims.n_layers, e.dims.n_kv_heads, e.dims.d_head);
    let cfg = RouterConfig {
        queue_depth: 8,
        sessions: SessionConfig::default(),
        pool_max_bytes: Some(200 * row),
        prefix_cache: None,
        ..RouterConfig::default()
    };
    let router = Router::start_with(EngineSpec::cpu(), &["llama_like".to_string()], cfg);
    let stats = router.stats("llama_like").unwrap();
    let mut rng = Rng::seed_from(19);
    let mut prompt =
        || gen_passkey(&mut rng, &PasskeySpec { n_filler: 60, n_digits: 8, depth: None }).prompt;

    // a session turn fits and stays resident
    let a = router
        .generate(
            "llama_like",
            GenerateParams::new(prompt())
                .lag(16)
                .ratio(0.5)
                .max_new(8)
                .session("mem")
                .into_request(1)
                .unwrap(),
        )
        .unwrap();
    assert!(a.error.is_none(), "session turn must fit: {:?}", a.error);
    let pool = router.pool("llama_like").unwrap();
    assert!(pool.resident_bytes() > 0, "detached session stays resident");

    // an oversized request is the typed rejection — and it must not shed
    // the stored session (shedding cannot make an impossible request fit)
    let d = router
        .generate(
            "llama_like",
            GenerateParams::new(prompt()).lag(16).ratio(0.5).max_new(600).into_request(2).unwrap(),
        )
        .unwrap();
    assert_eq!(d.error.as_ref().map(|er| er.code()), Some("pool-exhausted"));
    assert_eq!(stats.pool_rejected.load(Ordering::Relaxed), 1);
    assert_eq!(
        stats.sessions_shed.load(Ordering::Relaxed),
        0,
        "an impossible request must not destroy stored sessions"
    );
    assert!(pool.resident_bytes() > 0, "the session survives the rejection");

    // a fresh request that only fits once the LRU session is shed
    let b = router
        .generate(
            "llama_like",
            GenerateParams::new(prompt()).lag(16).ratio(0.5).max_new(100).into_request(3).unwrap(),
        )
        .unwrap();
    assert!(b.error.is_none(), "must recover by shedding: {:?}", b.error);
    assert!(stats.sessions_shed.load(Ordering::Relaxed) >= 1, "LRU session shed");

    // and the pool still serves right-sized work afterwards
    let c = router
        .generate(
            "llama_like",
            GenerateParams::new(prompt()).lag(16).ratio(0.5).max_new(8).into_request(4).unwrap(),
        )
        .unwrap();
    assert!(c.error.is_none(), "pool must recover: {:?}", c.error);
    router.shutdown();
}

/// Satellite-1 regression (coordinator byte reservations): cancelling a
/// request that reserved most of a budgeted pool must release its
/// reservation on the abort path, or every later right-sized request is
/// starved with `pool-exhausted` forever.
#[test]
fn cancel_under_budget_releases_the_reservation() {
    let e = engine();
    let prompt = long_chain_prompt(&e, 64);
    let row = lagkv::kvpool::row_bytes(e.dims.n_layers, e.dims.n_kv_heads, e.dims.d_head);
    let cfg = RouterConfig {
        queue_depth: 8,
        sessions: SessionConfig::default(),
        pool_max_bytes: Some(900 * row),
        prefix_cache: None,
        ..RouterConfig::default()
    };
    let router = Router::start_with(EngineSpec::cpu(), &["llama_like".to_string()], cfg);
    let stats = router.stats("llama_like").unwrap();

    // A reserves ~(prompt + 700) rows of the 900-row budget...
    let a = router
        .submit(
            "llama_like",
            GenerateParams::new(prompt.clone()).max_new(700).into_request(1).unwrap(),
        )
        .unwrap();
    let first = a.events.recv().unwrap();
    assert!(matches!(first, Event::Started { .. }), "got {first:?}");
    // ...and is cancelled mid-decode (the abort exit path).
    a.cancel();
    let resp = a.wait();
    assert_eq!(resp.error.as_ref().map(|er| er.code()), Some("cancelled"));

    // B needs most of the budget too: it only fits if A's reservation was
    // released on the cancel path.
    let b = router
        .generate(
            "llama_like",
            GenerateParams::new(prompt.clone()).max_new(700).into_request(2).unwrap(),
        )
        .unwrap();
    assert!(
        b.error.is_none(),
        "a leaked reservation starved admission: {:?}",
        b.error
    );
    assert_eq!(stats.pool_rejected.load(Ordering::Relaxed), 0);
    router.shutdown();
}

/// Satellite-3 regression: a prompt exceeding the largest prefill bucket
/// is a typed `bad-params` client error on the wire — never a stringly
/// `engine-failure`.
#[test]
fn overlong_prompt_is_typed_bad_params_on_the_wire() {
    let (_server, port, stop, accept) = boot_server();
    let mut client = Client::connect(port).unwrap();
    let prompt = "the of and to in is it on as with ".repeat(80); // >> 640 tokens
    let resp = client.generate(Some(1), GenerateParams::new(prompt).max_new(4)).unwrap();
    let err = resp.error.as_ref().expect("overlong prompt must error");
    assert_eq!(err.code(), "bad-params", "wire payload: {resp:?}");
    assert!(
        err.message().contains("prefill bucket"),
        "message must name the bound: {}",
        err.message()
    );
    stop.store(true, Ordering::Relaxed);
    accept.join().unwrap().unwrap();
}

/// Tentpole acceptance: a *legacy* bare request line (the pre-versioning
/// dialect, no `{"v":1,"op":...}` envelope) still round-trips through the
/// compat shim, and answers bit-identically to the equivalent v1 request.
/// This is the one sanctioned hand-written JSON line in the e2e tier.
#[test]
fn legacy_bare_request_line_round_trips_via_compat_shim() {
    let (_server, port, stop, accept) = boot_server();
    let mut client = Client::connect(port).unwrap();

    let legacy =
        r#"{"id": 100, "prompt": "the pass key is 11223344 <q> pass key <a>", "lag": 16, "ratio": 0.5, "max_new": 6, "seed": 0}"#;
    let raw = client.raw_call(legacy).unwrap();
    let legacy_resp = lagkv::api::response_from_json(&raw).unwrap();
    assert!(legacy_resp.error.is_none(), "legacy line failed: {legacy_resp:?}");
    assert_eq!(legacy_resp.id, 100);
    assert!(!legacy_resp.tokens.is_empty());

    // the same request through the v1 SDK decodes identically
    let params = GenerateParams::new("the pass key is 11223344 <q> pass key <a>")
        .lag(16)
        .ratio(0.5)
        .max_new(6);
    let v1_resp = client.generate(Some(101), params).unwrap();
    assert!(v1_resp.error.is_none());
    assert_eq!(v1_resp.tokens, legacy_resp.tokens, "shim must not change the generation");
    assert_eq!(v1_resp.text, legacy_resp.text);
    assert_eq!(v1_resp.cache_lens, legacy_resp.cache_lens);

    // legacy cancel lines are shimmed too (unknown id: acked, not found)
    let ack = client.raw_call(r#"{"cancel": 9999}"#).unwrap();
    let ack = lagkv::api::CancelAck::from_json(&ack).unwrap();
    assert!(!ack.found);

    // and an unversioned typo is still the strict typed rejection
    let bad = client.raw_call(r#"{"prompt": "x", "strem": true}"#).unwrap();
    let err = bad.get("error").unwrap();
    assert_eq!(err.get("code").unwrap().as_str().unwrap(), "bad-params");
    assert!(err.get("message").unwrap().as_str().unwrap().contains("strem"));

    stop.store(true, Ordering::Relaxed);
    accept.join().unwrap().unwrap();
}

/// Ops control plane over TCP: `info` reports engine facts, `stats`
/// reflects traffic, `sessions` lists and deletes stored conversations,
/// `drain` closes admission with the typed `draining` rejection while the
/// connection stays serviceable, and `undrain` reopens admission so the
/// next request is accepted again.
#[test]
fn tcp_control_plane_info_stats_sessions_drain() {
    let (_server, port, stop, accept) = boot_server();
    let mut client = Client::connect(port).unwrap();

    let info = client.info().unwrap();
    assert_eq!(info.version, lagkv::api::VERSION);
    assert_eq!(info.models.len(), 1);
    assert_eq!(info.models[0].model, "llama_like");
    assert!(info.models[0].max_prompt_tokens > 0);
    assert_eq!(info.policies.len(), PolicyKind::all().len());

    // one session turn of traffic
    let mut rng = Rng::seed_from(63);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 80, n_digits: 8, depth: None });
    let params = GenerateParams::new(item.prompt).lag(16).max_new(6).session("ops-chat");
    let resp = client.generate(Some(1), params).unwrap();
    assert!(resp.error.is_none(), "{resp:?}");

    // stats reflect it
    let stats = client.stats().unwrap();
    assert!(!stats.draining);
    let ms = &stats.models[0];
    assert!(ms.coord.completed >= 1, "{:?}", ms.coord);
    assert!(ms.pool.high_water_bytes > 0);

    // the session is listable and deletable (poll: the store entry lands
    // right after the terminal event)
    let mut listed = client.sessions(None).unwrap();
    for _ in 0..100 {
        if !listed.models[0].sessions.is_empty() {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
        listed = client.sessions(None).unwrap();
    }
    assert_eq!(listed.models[0].sessions.len(), 1, "{listed:?}");
    assert_eq!(listed.models[0].sessions[0].id, "ops-chat");
    assert_eq!(client.delete_session(Some("llama_like"), "ops-chat").unwrap(), 1);
    assert!(client.sessions(None).unwrap().models[0].sessions.is_empty());

    // drain: typed rejection, stats report it, the link stays up
    assert!(client.drain().unwrap().draining);
    let rejected = client.generate(Some(2), GenerateParams::new("post-drain")).unwrap();
    assert_eq!(rejected.error.as_ref().map(|e| e.code()), Some("draining"));
    assert!(client.stats().unwrap().draining);

    // undrain: the rollback half — admission reopens on the same link
    let reopened = client.undrain().unwrap();
    assert!(!reopened.draining, "undrain must report admission reopened");
    assert!(!client.stats().unwrap().draining);
    let accepted = client
        .generate(Some(3), GenerateParams::new("post-undrain").max_new(4))
        .unwrap();
    assert!(accepted.error.is_none(), "post-undrain submit must run: {accepted:?}");

    stop.store(true, Ordering::Relaxed);
    accept.join().unwrap().unwrap();
}

/// Tentpole e2e: a long cold prompt prefills chunk-by-chunk interleaved
/// with in-flight decode, so a streaming request keeps receiving tokens
/// while the newcomer prefills — the old batcher ran the whole prefill
/// inline in `admit`, stalling every live stream for its full duration.
/// Counted rather than timed (CI-safe): stream A must deliver tokens in
/// the window between B's submission and B's `Started` event, which fires
/// only when B's prefill completes.
#[test]
fn tcp_cold_prefill_interleaves_with_streaming_decode() {
    use std::time::Instant;

    let (_server, port, stop, accept) = boot_server();
    // A prompt whose greedy chain (policy: none) runs long enough that A
    // is still decoding throughout B's admission + chunked prefill.
    let chain = long_chain_prompt(&engine(), 300);

    let mut client_a = Client::connect(port).unwrap();
    let params_a = GenerateParams::new(chain).policy(PolicyKind::None).max_new(300);
    let mut stream_a = client_a.generate_stream(41, params_a).unwrap();

    // wait until A is demonstrably decoding before B shows up
    let mut a_token_times: Vec<Instant> = Vec::new();
    while a_token_times.len() < 2 {
        match stream_a.next().unwrap() {
            Some(StreamItem::Event(Event::Token { .. })) => a_token_times.push(Instant::now()),
            Some(StreamItem::Event(Event::Error { error, .. })) => {
                panic!("stream A died before B arrived: {error}")
            }
            Some(_) => {}
            None => panic!("stream A ended before B arrived"),
        }
    }

    // B: a long cold prompt (~550 tokens -> the 640 bucket, many chunks)
    let b_thread = std::thread::spawn(move || {
        let long_prompt = "the of and to in is it on as with ".repeat(55);
        let mut client_b = Client::connect(port).unwrap();
        let params_b = GenerateParams::new(long_prompt).lag(16).ratio(0.5).max_new(4);
        let t_submit = Instant::now();
        let mut stream_b = client_b.generate_stream(42, params_b).unwrap();
        let mut t_started = None;
        let mut b_tokens = 0usize;
        while let Some(item) = stream_b.next().unwrap() {
            match item {
                StreamItem::Event(Event::Started { .. }) => t_started = Some(Instant::now()),
                StreamItem::Event(Event::Token { .. }) => b_tokens += 1,
                StreamItem::Event(Event::Error { error, .. }) => {
                    panic!("request B failed: {error}")
                }
                _ => {}
            }
        }
        assert!(b_tokens > 0, "B must decode after its chunked prefill");
        (t_submit, t_started.expect("B never saw Started"))
    });

    // keep draining A the whole time, timestamping every token
    loop {
        match stream_a.next().unwrap() {
            Some(StreamItem::Event(Event::Token { .. })) => a_token_times.push(Instant::now()),
            Some(StreamItem::Event(Event::Error { error, .. })) => {
                panic!("stream A failed: {error}")
            }
            Some(_) => {}
            None => break,
        }
    }
    let (t_submit, t_started) = b_thread.join().unwrap();

    assert!(
        t_started >= t_submit,
        "Started cannot precede the submit that caused it"
    );
    let interleaved = a_token_times
        .iter()
        .filter(|&&t| t > t_submit && t < t_started)
        .count();
    assert!(
        interleaved >= 2,
        "stream A got only {interleaved} token(s) while B's cold prompt prefilled — \
         the batcher stalled decode for the whole prefill ({} A tokens total)",
        a_token_times.len()
    );

    stop.store(true, Ordering::Relaxed);
    accept.join().unwrap().unwrap();
}

/// Tentpole e2e: with the radix prefix cache enabled, a second sequence
/// sharing a long prompt prefix attaches it CoW (`reused_tokens > 0`) and
/// decodes bit-identically to the same request on a cache-less router.
#[test]
fn router_prefix_cache_reuses_shared_prompt_prefix() {
    use lagkv::kvpool::PrefixConfig;

    let warm_cfg = RouterConfig {
        prefix_cache: Some(PrefixConfig { stride: 24, ..Default::default() }),
        ..RouterConfig::default()
    };
    let warm = Router::start_with(EngineSpec::cpu(), &["llama_like".to_string()], warm_cfg);
    let cold = Router::start(EngineSpec::cpu(), &["llama_like".to_string()]);

    let mut rng = Rng::seed_from(51);
    let sys = gen_passkey(&mut rng, &PasskeySpec { n_filler: 120, n_digits: 16, depth: None })
        .prompt;
    let mk = |q: &str, id: u64| {
        GenerateParams::new(format!("{sys} {q}"))
            .lag(16)
            .ratio(0.5)
            .max_new(8)
            .into_request(id)
            .unwrap()
    };
    let w1 = warm.generate("llama_like", mk("<q> the pass key <a>", 1)).unwrap();
    assert!(w1.error.is_none(), "{:?}", w1.error);
    assert_eq!(w1.reused_tokens, 0, "nothing to reuse on a cold tree");
    let w2 = warm.generate("llama_like", mk("<q> remember the words <a>", 2)).unwrap();
    assert!(w2.error.is_none(), "{:?}", w2.error);
    assert!(w2.reused_tokens > 0, "shared prefix must hit the cache");

    let c2 = cold.generate("llama_like", mk("<q> remember the words <a>", 3)).unwrap();
    assert!(c2.error.is_none(), "{:?}", c2.error);
    assert_eq!(w2.tokens, c2.tokens, "prefix-hit decode must equal cold decode");
    assert_eq!(w2.text, c2.text);
    assert_eq!(w2.cache_lens, c2.cache_lens, "Eq. 10 trajectory must be unchanged");
    assert_eq!(c2.reused_tokens, 0);

    let prefix = warm.prefix_cache("llama_like").unwrap();
    let s = prefix.stats();
    assert!(s.hits >= 1, "hit gauge: {s:?}");
    assert!(s.entries >= 2, "snapshots + finals stored: {s:?}");
    warm.shutdown();
    cold.shutdown();
}

/// The bounded admission queue rejects overflow with a typed `queue-full`
/// error while accepted requests still complete.
#[test]
fn queue_overflow_is_a_typed_error() {
    let cfg = RouterConfig { queue_depth: 1, ..RouterConfig::default() };
    let router = Router::start_with(EngineSpec::cpu(), &["llama_like".to_string()], cfg);
    let mut rng = Rng::seed_from(3);
    let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 100, n_digits: 8, depth: None });
    let mut handles = Vec::new();
    let mut rejected = 0usize;
    for id in 0..10u64 {
        let req = GenerateParams::new(item.prompt.clone())
            .lag(16)
            .max_new(12)
            .into_request(id)
            .unwrap();
        match router.submit("llama_like", req) {
            Ok(h) => handles.push(h),
            Err(e) => {
                assert_eq!(e.code(), "queue-full");
                rejected += 1;
            }
        }
    }
    // 4 decode slots + a queue depth of 1 cannot absorb 10 instant submits.
    assert!(rejected >= 1, "expected at least one queue-full rejection");
    assert!(!handles.is_empty(), "the first submit always fits");
    for h in handles {
        let r = h.wait();
        assert!(r.error.is_none(), "accepted request failed: {:?}", r.error);
    }
    router.shutdown();
}

//! Golden-vector tests: the pure-Rust scorers, top-k convention, and
//! tokenizer must agree with the python jnp oracles byte-for-byte-ish.
//! Vectors are emitted by python/compile/aot.py into artifacts/golden/.
//!
//! These tests SKIP (with a loud message) when artifacts are absent so that
//! `cargo test` works before `make artifacts`; CI runs them after.

use std::path::PathBuf;

use lagkv::compress::scores;
use lagkv::compress::topk::topk_indices;
use lagkv::config::read_json;
use lagkv::tokenizer::Tokenizer;

fn art() -> Option<PathBuf> {
    let p = PathBuf::from(std::env::var("LAGKV_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    if p.join("golden").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts/golden (run `make artifacts`)");
        None
    }
}

#[test]
fn scores_match_python_oracle() {
    let Some(art) = art() else { return };
    let v = read_json(&art.join("golden/scores.json")).unwrap();
    let h = v.get("h").unwrap().as_usize().unwrap();
    let d = v.get("d").unwrap().as_usize().unwrap();
    for case in v.get("cases").unwrap().as_arr().unwrap() {
        let l = case.get("l").unwrap().as_usize().unwrap();
        let kc = case.get("k_cur").unwrap().as_f32_vec().unwrap();
        let vc = case.get("v_cur").unwrap().as_f32_vec().unwrap();
        let kr = case.get("k_ref").unwrap().as_f32_vec().unwrap();
        let vr = case.get("v_ref").unwrap().as_f32_vec().unwrap();
        let want_lag = case.get("lagkv").unwrap().as_f32_vec().unwrap();
        let want_local = case.get("localkv").unwrap().as_f32_vec().unwrap();
        let want_l2 = case.get("l2norm").unwrap().as_f32_vec().unwrap();
        for head in 0..h {
            let s = |x: &[f32]| x[head * l * d..(head + 1) * l * d].to_vec();
            let got = scores::lagkv_score(&s(&kc), &s(&vc), &s(&kr), &s(&vr), l, d);
            for (i, (&g, &w)) in got.iter().zip(&want_lag[head * l..(head + 1) * l]).enumerate()
            {
                assert!(
                    (g - w).abs() < 2e-5,
                    "lagkv mismatch l={l} head={head} i={i}: {g} vs {w}"
                );
            }
            let got = scores::localkv_score(&s(&kc), &s(&vc), l, d);
            for (&g, &w) in got.iter().zip(&want_local[head * l..(head + 1) * l]) {
                assert!((g - w).abs() < 2e-5, "localkv mismatch: {g} vs {w}");
            }
            let got = scores::l2norm_score(&s(&kc), l, d);
            for (&g, &w) in got.iter().zip(&want_l2[head * l..(head + 1) * l]) {
                assert!((g - w).abs() < 2e-4, "l2norm mismatch: {g} vs {w}");
            }
        }
    }
}

#[test]
fn topk_matches_python_convention() {
    let Some(art) = art() else { return };
    let v = read_json(&art.join("golden/topk.json")).unwrap();
    let scores_flat = v.get("scores").unwrap().as_f32_vec().unwrap();
    let k = v.get("k").unwrap().as_usize().unwrap();
    let want = v.get("idx").unwrap().as_usize_vec().unwrap();
    let h = want.len() / k;
    let l = scores_flat.len() / h;
    for head in 0..h {
        let got = topk_indices(&scores_flat[head * l..(head + 1) * l], k);
        assert_eq!(got, want[head * k..(head + 1) * k].to_vec(), "head {head}");
    }
}

#[test]
fn tokenizer_matches_python() {
    let Some(art) = art() else { return };
    let v = read_json(&art.join("golden/tokenizer.json")).unwrap();
    for (variant, dpt) in [("llama_like", 3usize), ("qwen_like", 1usize)] {
        let tok = Tokenizer::load(&art.join("models").join(variant), dpt).unwrap();
        for case in v.get(variant).unwrap().as_arr().unwrap() {
            let text = case.get("text").unwrap().as_str().unwrap();
            let want: Vec<i32> = case
                .get("ids")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_i64().unwrap() as i32)
                .collect();
            let got = tok.encode(text, false);
            assert_eq!(got, want, "{variant}: {text:?}");
        }
    }
}

//! Integration tests over the full PJRT stack: runtime + AOT artifacts +
//! engine + compression driver + coordinator.  These need `--features xla`
//! (with a real `xla` binding) *and* `make artifacts`; they SKIP loudly
//! otherwise so the default `cargo test` stays hermetic.  The hermetic
//! end-to-end coverage lives in rust/tests/backend_e2e.rs on the CPU
//! reference backend.

#[cfg(not(feature = "xla"))]
#[test]
fn xla_integration_requires_feature() {
    eprintln!(
        "SKIP: PJRT integration tests need `cargo test --features xla` \
         (with the real xla binding) and `make artifacts`"
    );
}

#[cfg(feature = "xla")]
mod xla_stack {
    use std::path::PathBuf;

    use lagkv::compress::policy::{make_policy, PartitionInput, Scorer};
    use lagkv::config::{read_json, CompressionConfig, PolicyKind, ScorerBackend};
    use lagkv::engine::Engine;
    use lagkv::kvcache::ratio;
    use lagkv::util::rng::Rng;
    use lagkv::workloads::passkey::{gen_passkey, PasskeySpec};

    fn art() -> Option<PathBuf> {
        let p =
            PathBuf::from(std::env::var("LAGKV_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
        if p.join("manifest.json").exists() && p.join("models/llama_like/weights.npz").exists() {
            Some(p)
        } else {
            eprintln!("SKIP: artifacts incomplete (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn engine_loads_and_reports_dims() {
        let Some(art) = art() else { return };
        let e = Engine::load(&art, "llama_like").unwrap();
        assert!(e.dims.n_layers >= 2);
        assert_eq!(e.dims.n_q_heads % e.dims.n_kv_heads, 0);
        let entries = e.backend().entries();
        assert!(entries.iter().any(|x| x.starts_with("prefill_t")));
        assert!(entries.iter().any(|x| x.starts_with("decode_b")));
        assert!(entries.iter().any(|x| x.starts_with("lagkv_score_l")));
    }

    #[test]
    fn prefill_decode_replays_python_golden() {
        let Some(art) = art() else { return };
        let golden_path = art.join("golden/model_e2e.json");
        if !golden_path.exists() {
            eprintln!("SKIP: no model_e2e.json golden");
            return;
        }
        let g = read_json(&golden_path).unwrap();
        let e = Engine::load(&art, "llama_like").unwrap();
        let ids: Vec<i32> = g
            .get("prompt_ids")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap() as i32)
            .collect();
        // prompt_ids were produced by the same tokenizer: cross-check
        let text = g.get("prompt").unwrap().as_str().unwrap();
        assert_eq!(e.tokenizer.encode(text, true), ids);

        // first: prefill logits must match python's (layout / weight order)
        let want_logits: Vec<f32> = g.get("logits_first5").unwrap().as_arr().unwrap()[0]
            .as_f32_vec()
            .unwrap();
        let (logits, _cache) = e.prefill(&ids).unwrap();
        for (i, (&got, &want)) in logits.iter().zip(&want_logits).enumerate() {
            assert!(
                (got - want).abs() < 1e-3,
                "prefill logit {i}: rust {got} vs python {want} (full rust: {:?})",
                &logits[..5]
            );
        }

        let want_tokens: Vec<i32> = g
            .get("greedy_tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap() as i32)
            .collect();
        let cfg = CompressionConfig { policy: PolicyKind::None, ..Default::default() };
        let out = e.generate_ids(&ids, &cfg, want_tokens.len(), 0).unwrap();
        assert_eq!(
            &out.tokens[..want_tokens.len().min(out.tokens.len())],
            &want_tokens[..want_tokens.len().min(out.tokens.len())],
            "rust greedy decode disagrees with python"
        );
    }

    #[test]
    fn xla_scorer_matches_rust_scorer() {
        let Some(art) = art() else { return };
        let e = Engine::load(&art, "llama_like").unwrap();
        let cfg = CompressionConfig {
            policy: PolicyKind::LagKv,
            scorer: ScorerBackend::Xla,
            lag: 16,
            ..Default::default()
        };
        let mut xla = e.make_scorer(&cfg, 0);
        let mut rust = make_policy(PolicyKind::LagKv, 0);
        let mut rng = Rng::seed_from(3);
        let (l, d) = (16usize, e.dims.d_head);
        for case in 0..4 {
            let mk = |rng: &mut Rng| -> Vec<f32> { (0..l * d).map(|_| rng.normal()).collect() };
            let kc = mk(&mut rng);
            let vc = mk(&mut rng);
            let kr = mk(&mut rng);
            let vr = mk(&mut rng);
            let pos: Vec<i32> = (0..l as i32).collect();
            let attn = vec![0.0f32; l];
            let inp = PartitionInput {
                layer: 0,
                head: case % 2,
                k_cur: &kc,
                v_cur: &vc,
                k_ref: &kr,
                v_ref: &vr,
                attn_acc: &attn,
                positions: &pos,
                l,
                d,
            };
            let a = xla.score(&inp).unwrap();
            let b = rust.score(&inp).unwrap();
            for (i, (&x, &y)) in a.iter().zip(&b).enumerate() {
                assert!(
                    (x - y).abs() < 1e-5,
                    "xla vs rust scorer mismatch at case {case} i={i}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn generation_cache_length_matches_eq10() {
        let Some(art) = art() else { return };
        let e = Engine::load(&art, "llama_like").unwrap();
        let mut rng = Rng::seed_from(11);
        let item =
            gen_passkey(&mut rng, &PasskeySpec { n_filler: 200, n_digits: 16, depth: None });
        let cfg = CompressionConfig {
            policy: PolicyKind::LagKv,
            sink: 4,
            lag: 16,
            ratio: 0.25,
            ..Default::default()
        };
        let max_new = 8;
        let out = e.generate(&item.prompt, &cfg, max_new, 0).unwrap();
        // the last generated token is returned but never appended (no decode
        // step consumed it), so the cache holds total-1 rows
        let total = out.prompt_tokens + out.tokens.len() - 1;
        let want = ratio::retained_len(total, cfg.sink, cfg.lag, cfg.keep_per_partition());
        for (layer, &len) in out.cache_lens.iter().enumerate() {
            assert_eq!(
                len, want,
                "layer {layer}: cache len {len} != Eq.10 {want} (total {total})"
            );
        }
        assert!(out.compression_events > 0, "compression must have fired");
    }

    #[test]
    fn every_policy_generates() {
        let Some(art) = art() else { return };
        let e = Engine::load(&art, "llama_like").unwrap();
        let mut rng = Rng::seed_from(12);
        let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 120, n_digits: 8, depth: None });
        for &policy in PolicyKind::all() {
            let cfg = CompressionConfig {
                policy,
                sink: 4,
                lag: 16,
                ratio: 0.5,
                skip_layers: if policy == PolicyKind::L2Norm { 2 } else { 0 },
                ..Default::default()
            };
            let out = e.generate(&item.prompt, &cfg, 6, 0).unwrap();
            assert_eq!(out.tokens.len().min(6), out.tokens.len());
            if policy == PolicyKind::L2Norm {
                // skipped layers stay uncompressed -> longer caches
                assert!(out.cache_lens[0] >= out.cache_lens[e.dims.n_layers - 1]);
            }
        }
    }

    #[test]
    fn compression_preserves_baseline_answer_at_2x() {
        // Soft end-to-end sanity: at r=2x with large L the answer tokens
        // usually survive.  We assert the run completes and the cache is
        // strictly smaller than baseline (quality asserted statistically in
        // the harness, not per-item here).
        let Some(art) = art() else { return };
        let e = Engine::load(&art, "llama_like").unwrap();
        let mut rng = Rng::seed_from(13);
        let item =
            gen_passkey(&mut rng, &PasskeySpec { n_filler: 260, n_digits: 16, depth: Some(0.3) });
        let base = CompressionConfig { policy: PolicyKind::None, ..Default::default() };
        let comp = CompressionConfig {
            policy: PolicyKind::LagKv,
            sink: 4,
            lag: 64,
            ratio: 0.5,
            ..Default::default()
        };
        let b = e.generate(&item.prompt, &base, 10, 0).unwrap();
        let c = e.generate(&item.prompt, &comp, 10, 0).unwrap();
        assert!(c.cache_lens[0] < b.cache_lens[0]);
    }

    #[test]
    fn batched_decode_matches_single() {
        // The same prompt decoded alone (bucket 1 via generate) and inside a
        // shared batch must produce identical tokens (slot independence).
        let Some(art) = art() else { return };
        let e = Engine::load(&art, "llama_like").unwrap();
        if !e.decode_buckets().contains(&4) {
            eprintln!("SKIP: no b=4 decode bucket");
            return;
        }
        let mut rng = Rng::seed_from(14);
        let prompts: Vec<String> = (0..2)
            .map(|_| {
                gen_passkey(&mut rng, &PasskeySpec { n_filler: 60, n_digits: 6, depth: None })
                    .prompt
            })
            .collect();
        let cfg = CompressionConfig {
            policy: PolicyKind::LagKv,
            lag: 16,
            ratio: 0.5,
            sink: 4,
            ..Default::default()
        };

        let solo: Vec<Vec<i32>> = prompts
            .iter()
            .map(|p| e.generate(p, &cfg, 5, 0).unwrap().tokens)
            .collect();

        // batch: 2 occupied + 2 idle slots
        use lagkv::engine::SlotState;
        use lagkv::util::argmax;
        let mut slots: Vec<SlotState> = Vec::new();
        for p in &prompts {
            let ids = e.tokenizer.encode(p, true);
            let (logits, cache) = e.prefill(&ids).unwrap();
            let first = argmax(&logits) as i32;
            let scorer = e.make_scorer(&cfg, 0);
            let mut slot = SlotState::occupied(cache, cfg.clone(), scorer, first, 5);
            if let Some(seq) = slot.active_mut() {
                let ev =
                    lagkv::compress::maybe_compress(&mut seq.cache, &cfg, seq.scorer.as_mut())
                        .unwrap();
                seq.compression_events += ev.len();
                seq.push_generated(first, e.tmax);
            }
            slots.push(slot);
        }
        slots.push(SlotState::idle());
        slots.push(SlotState::idle());
        while slots.iter().any(|s| s.active().is_some()) {
            e.step_batch(&mut slots).unwrap();
        }
        for (i, want) in solo.iter().enumerate() {
            let got = slots[i].take().unwrap().generated;
            assert_eq!(&got, want, "slot {i} diverged from solo decode");
        }
    }
}

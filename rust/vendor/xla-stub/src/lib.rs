//! Type-level stub of the PJRT (`xla`) bindings.
//!
//! This crate mirrors the exact API surface the lagkv runtime and XLA
//! backend consume — `PjRtClient`, `PjRtLoadedExecutable`, `PjRtBuffer`,
//! `Literal`, `HloModuleProto`, `XlaComputation`, and the `FromRawBytes`
//! npz loader — so the feature-gated PJRT path stays compiling (and
//! reviewable) on machines without the XLA shared libraries.  Every
//! operation that would touch PJRT returns [`Error::StubUnavailable`];
//! nothing panics, so `lagkv --backend xla` degrades into a clean runtime
//! error instead of a crash.

use std::path::Path;

/// Stub error: every PJRT entry point produces this.
#[derive(Debug, Clone)]
pub enum Error {
    /// The stub cannot execute anything; swap in the real binding.
    StubUnavailable(&'static str),
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::StubUnavailable(what))
}

// -- element types ------------------------------------------------------------

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Element types the lagkv artifacts use (f32 tensors, i32 index tensors).
pub trait NativeType: sealed::Sealed + Copy + Default + 'static {
    const NAME: &'static str;
}

impl NativeType for f32 {
    const NAME: &'static str = "f32";
}

impl NativeType for i32 {
    const NAME: &'static str = "i32";
}

// -- literals -----------------------------------------------------------------

/// Host-side tensor value.  The stub stores nothing; constructors succeed
/// (shape bookkeeping only) and host<->device transfers fail.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    dims: Vec<i64>,
}

/// Array shape of a literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64] }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal { dims: vec![] }
    }

    /// Reinterpret with a new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = self.dims.iter().product();
        let m: i64 = dims.iter().product();
        if n != m {
            return unavailable("reshape: element count mismatch");
        }
        Ok(Literal { dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// npz weight loading (real binding reads `weights.npz`).
pub trait FromRawBytes: Sized {
    type Context;
    fn read_npz<P: AsRef<Path>>(path: P, ctx: &Self::Context) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    type Context = ();

    fn read_npz<P: AsRef<Path>>(_path: P, _ctx: &Self::Context) -> Result<Vec<(String, Self)>> {
        unavailable("Literal::read_npz")
    }
}

// -- HLO artifacts ------------------------------------------------------------

/// Parsed HLO module (text interchange format).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation ready for compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

// -- PJRT ---------------------------------------------------------------------

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Something a compiled executable can take as an argument: a host literal
/// (uploaded per call) or an already-device-resident buffer.
pub trait BufferArgument: sealed_arg::SealedArg {}

mod sealed_arg {
    pub trait SealedArg {}
    impl SealedArg for super::Literal {}
    impl<'a> SealedArg for &'a super::PjRtBuffer {}
}

impl BufferArgument for Literal {}
impl<'a> BufferArgument for &'a PjRtBuffer {}

/// Compiled + loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with host-literal arguments.  Outer vec: devices; inner:
    /// outputs (the lagkv artifacts return a single tuple).
    pub fn execute<T: BufferArgument>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    /// Execute with device-buffer arguments (no host transfer).
    pub fn execute_b<T: BufferArgument>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client handle (CPU plugin in the real binding).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The stub has no PJRT plugin: constructing the client fails, which is
    /// what surfaces the "swap in the real binding" message to users.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu (stub build: no XLA shared libraries)")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_bookkeeping_works() {
        let l = Literal::vec1(&[1.0f32; 12]);
        let r = l.reshape(&[3, 4]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[3, 4]);
        assert!(l.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn runtime_entry_points_fail_loudly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
        let l = Literal::scalar(3i32);
        assert!(l.to_vec::<i32>().is_err());
    }
}

//! Hermetic, dependency-free subset of the `anyhow` error-handling crate.
//!
//! The lagkv workspace builds on machines with no network access and no
//! registry cache, so this small in-tree crate provides the exact surface
//! the codebase uses:
//!
//! * [`Error`] — an opaque error with a context chain,
//! * [`Result<T>`] — `std::result::Result<T, Error>`,
//! * [`anyhow!`] / [`bail!`] — formatted-error construction macros,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Semantics match real `anyhow` where the codebase can observe them:
//! `Display` shows the outermost message, `{:#}` (alternate) shows the
//! whole chain joined by `": "`, and `Debug` shows the chain with a
//! `Caused by:` trailer.

use std::fmt;

/// An error with an optional chain of wrapped causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let chain = self.chain();
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts into [`Error`] (this is what makes `?` work on
/// io/parse errors inside `anyhow::Result` functions).  Note that `Error`
/// itself deliberately does NOT implement `std::error::Error`, exactly like
/// real anyhow, so this blanket impl cannot collide with `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as context layers.
        let mut msgs = Vec::new();
        msgs.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(msgs.pop().expect("at least one message"));
        while let Some(m) = msgs.pop() {
            err = err.context(m);
        }
        err
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_and_alternate_show_chain() {
        let e = Error::msg("root").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: root");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("missing thing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening file").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening file: missing thing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("no value {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "no value 7");

        let ok: Option<u32> = Some(3);
        assert_eq!(ok.context("unused").unwrap(), 3);
    }

    #[test]
    fn context_on_anyhow_result_stacks() {
        fn inner() -> Result<()> {
            bail!("bad state {}", 42)
        }
        let e = inner().context("while validating").unwrap_err();
        assert_eq!(format!("{e:#}"), "while validating: bad state 42");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("x = {}, y = {y:?}", 1, y = "z");
        assert_eq!(format!("{e}"), "x = 1, y = \"z\"");
    }

    #[test]
    fn debug_shows_caused_by() {
        let e = Error::msg("root").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("root"));
    }
}

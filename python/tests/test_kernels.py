"""Kernel-vs-reference correctness: the CORE L1 signal.

Every Pallas kernel is swept against its pure-jnp oracle with hypothesis
over shapes, value ranges, and adversarial inputs (constant channels,
outlier tokens, denormal-ish magnitudes).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, lagkv_score, ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, shape, scale=1.0, offset=0.0):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale + offset)


# ---------------------------------------------------------------------------
# lagkv_scores
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    h=st.sampled_from([1, 2, 4]),
    l=st.sampled_from([8, 16, 64]),
    d=st.sampled_from([4, 32]),
    scale=st.sampled_from([1e-3, 1.0, 50.0]),
    offset=st.sampled_from([0.0, -7.5, 100.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lagkv_matches_ref(h, l, d, scale, offset, seed):
    rng = np.random.default_rng(seed)
    kc, vc, kr, vr = (rand(rng, (h, l, d), scale, offset) for _ in range(4))
    got = lagkv_score.lagkv_scores(kc, vc, kr, vr)
    want = ref.lagkv_scores_ref(kc, vc, kr, vr)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_lagkv_scores_are_distributions():
    rng = np.random.default_rng(0)
    s = lagkv_score.lagkv_scores(*(rand(rng, (2, 16, 8)) for _ in range(4)))
    # Eq. 9 sums two softmaxes -> each head row sums to 2.
    np.testing.assert_allclose(np.asarray(s).sum(axis=1), 2.0, rtol=1e-5)
    assert (np.asarray(s) > 0).all()


def test_lagkv_constant_channel_is_stable():
    """A channel that is constant in the reference (max==min) must not
    produce NaN/inf — the EPS guard covers degenerate normalization."""
    rng = np.random.default_rng(1)
    kc, vc = rand(rng, (1, 8, 4)), rand(rng, (1, 8, 4))
    kr = jnp.zeros((1, 8, 4))
    vr = jnp.ones((1, 8, 4))
    s = np.asarray(lagkv_score.lagkv_scores(kc, vc, kr, vr))
    assert np.isfinite(s).all()


def test_lagkv_outlier_token_wins():
    """A token incoherent with the lag reference gets the top score — the
    paper's core mechanism ('finds tokens not coherent to the next chunk')."""
    rng = np.random.default_rng(2)
    l = 16
    kc = rand(rng, (1, l, 8), scale=0.1)
    vc = rand(rng, (1, l, 8), scale=0.1)
    kr = rand(rng, (1, l, 8), scale=0.1)
    vr = rand(rng, (1, l, 8), scale=0.1)
    kc = kc.at[0, 5].set(25.0)  # outlier vs the reference's min/max band
    s = np.asarray(lagkv_score.lagkv_scores(kc, vc, kr, vr))
    assert s[0].argmax() == 5


def test_localkv_matches_ref():
    rng = np.random.default_rng(3)
    kc, vc = rand(rng, (4, 32, 16)), rand(rng, (4, 32, 16))
    got = lagkv_score.localkv_scores(kc, vc)
    want = ref.localkv_scores_ref(kc, vc)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


@settings(max_examples=15, deadline=None)
@given(
    h=st.sampled_from([1, 2]),
    l=st.sampled_from([8, 64]),
    d=st.sampled_from([4, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_l2norm_matches_ref(h, l, d, seed):
    rng = np.random.default_rng(seed)
    kc = rand(rng, (h, l, d), scale=3.0)
    got = lagkv_score.l2norm_scores(kc)
    want = ref.l2norm_scores_ref(kc)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    hq=st.sampled_from([2, 8]),
    hkv=st.sampled_from([1, 2]),
    t=st.sampled_from([64, 128]),
    d=st.sampled_from([8, 32]),
    frac=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(hq, hkv, t, d, frac, seed):
    if hq % hkv:
        hq = hkv * (hq // hkv + 1)
    rng = np.random.default_rng(seed)
    q = rand(rng, (hq, d))
    k = rand(rng, (hkv, t, d))
    v = rand(rng, (hkv, t, d))
    length = max(1, int(frac * t))
    got = attention.decode_attention(q, k, v, length, blk=32)
    want, _ = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-6)


def test_decode_attention_ignores_garbage_rows():
    """Rows beyond `length` must have zero influence."""
    rng = np.random.default_rng(7)
    q = rand(rng, (4, 16))
    k = rand(rng, (2, 64, 16))
    v = rand(rng, (2, 64, 16))
    length = 20
    k2 = k.at[:, length:].set(1e4)
    v2 = v.at[:, length:].set(-1e4)
    a = attention.decode_attention(q, k, v, length, blk=16)
    b = attention.decode_attention(q, k2, v2, length, blk=16)
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_instrumented_probs_match_ref():
    rng = np.random.default_rng(8)
    q = rand(rng, (8, 16))
    k = rand(rng, (2, 64, 16))
    v = rand(rng, (2, 64, 16))
    out, probs_kv = attention.decode_attention_probs(q, k, v, 40)
    want_out, want_p = ref.decode_attention_ref(q, k, v, 40)
    np.testing.assert_allclose(out, want_out, rtol=3e-5, atol=3e-6)
    want_kv = np.asarray(want_p).reshape(2, 4, 64).sum(axis=1)
    np.testing.assert_allclose(probs_kv, want_kv, rtol=3e-5, atol=3e-6)
    # probability mass: each q-head row sums to 1 -> group rows sum to group
    np.testing.assert_allclose(np.asarray(probs_kv).sum(axis=1), 4.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# top-k selection convention
# ---------------------------------------------------------------------------


def test_topk_indices_sorted_unique():
    rng = np.random.default_rng(9)
    s = jnp.asarray(rng.standard_normal((4, 32), dtype=np.float32))
    idx = np.asarray(ref.topk_indices_ref(s, 8))
    assert idx.shape == (4, 8)
    for row in idx:
        assert (np.diff(row) > 0).all()  # strictly ascending => unique

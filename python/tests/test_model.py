"""L2 model invariants: causality, RoPE position-stability under eviction,
prefill/decode agreement, GQA shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common as C
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = C.ModelConfig(name="test", d_model=64, n_layers=2, n_q_heads=4, n_kv_heads=2, d_head=16, d_ff=96, max_seq=64)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=1)


def _prefill(params, ids, bucket=32):
    tokens = np.full((bucket,), C.PAD, np.int32)
    tokens[: len(ids)] = ids
    return M.prefill(CFG, params, jnp.asarray(tokens), len(ids))


def test_prefill_shapes(params):
    logits, ks, vs, sums = _prefill(params, [1, 8, 9, 10])
    assert logits.shape == (CFG.vocab_size,)
    assert ks.shape == (CFG.n_layers, CFG.n_kv_heads, 32, CFG.d_head)
    assert vs.shape == ks.shape
    assert sums.shape == (CFG.n_layers, CFG.n_kv_heads, 32)


def test_prefill_causality(params):
    """Changing tokens AFTER position true_len-1 must not change the
    last-position logits (they are padding)."""
    ids = [1, 8, 9, 10, 11]
    l1, *_ = _prefill(params, ids)
    tokens2 = np.full((32,), 77, np.int32)
    tokens2[: len(ids)] = ids
    l2, *_ = M.prefill(CFG, params, jnp.asarray(tokens2), len(ids))
    np.testing.assert_allclose(l1, l2, rtol=1e-5)


def test_prefill_padding_invariance(params):
    """Same prompt through two bucket sizes gives the same last logits."""
    ids = [1, 8, 9, 10, 11, 12]
    l1, *_ = _prefill(params, ids, bucket=32)
    l2, *_ = _prefill(params, ids, bucket=64)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-5)


def test_attn_sums_mass(params):
    """Total attention mass = number of valid query rows, per layer/group."""
    ids = [1, 8, 9, 10, 11, 12, 13]
    _, _, _, sums = _prefill(params, ids)
    got = np.asarray(sums).sum(axis=2)  # [nl, hkv]
    group = CFG.n_q_heads // CFG.n_kv_heads
    np.testing.assert_allclose(got, len(ids) * group, rtol=1e-4)


def _mk_cache(ks, vs, n, tmax=64):
    nl, hkv, _, dh = ks.shape
    kc = np.zeros((nl, 1, hkv, tmax, dh), np.float32)
    vc = np.zeros_like(kc)
    kc[:, 0, :, :n] = np.asarray(ks)[:, :, :n]
    vc[:, 0, :, :n] = np.asarray(vs)[:, :, :n]
    return jnp.asarray(kc), jnp.asarray(vc)


def test_prefill_decode_agreement(params):
    """Prefill over [t0..t5] == prefill over [t0..t4] + decode_step(t5)."""
    ids = [1, 8, 9, 10, 11, 12]
    l_full, *_ = _prefill(params, ids)
    l_pre, ks, vs, _ = _prefill(params, ids[:-1])
    kc, vc = _mk_cache(ks, vs, len(ids) - 1)
    logits, kn, vn, ko, vo, row = M.decode_step(
        CFG,
        params,
        kc,
        vc,
        jnp.full((CFG.n_layers, 1), len(ids) - 1, jnp.int32),
        jnp.asarray([len(ids) - 1], jnp.int32),
        jnp.asarray([ids[-1]], jnp.int32),
    )
    np.testing.assert_allclose(logits[0], l_full, rtol=2e-4, atol=1e-5)


def test_decode_appends_in_graph(params):
    ids = [1, 8, 9]
    _, ks, vs, _ = _prefill(params, ids)
    kc, vc = _mk_cache(ks, vs, 3)
    _, kn, vn, ko, vo, _ = M.decode_step(
        CFG, params, kc, vc,
        jnp.full((CFG.n_layers, 1), 3, jnp.int32),
        jnp.asarray([3], jnp.int32), jnp.asarray([10], jnp.int32),
    )
    # appended row equals the returned new K/V
    np.testing.assert_allclose(np.asarray(ko)[:, 0, :, 3], np.asarray(kn)[:, 0], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(vo)[:, 0, :, 3], np.asarray(vn)[:, 0], rtol=1e-6)
    # earlier rows untouched
    np.testing.assert_allclose(np.asarray(ko)[:, 0, :, :3], np.asarray(kc)[:, 0, :, :3], rtol=1e-6)


def test_eviction_position_stability(params):
    """Decode logits depend on WHICH rows are in the cache, not on where
    they sit after compaction: dropping row j then compacting must equal
    attention over the surviving rows in any layout.  This is the property
    that makes LagKV eviction sound with RoPE-at-write."""
    ids = [1, 8, 9, 10, 11, 12, 13, 14]
    n = len(ids)
    _, ks, vs, _ = _prefill(params, ids)
    ks, vs = np.asarray(ks), np.asarray(vs)

    # evict row 3 everywhere, compact
    keep = [i for i in range(n) if i != 3]
    kc = np.zeros((CFG.n_layers, 1, CFG.n_kv_heads, 64, CFG.d_head), np.float32)
    vc = np.zeros_like(kc)
    kc[:, 0, :, : n - 1] = ks[:, :, keep]
    vc[:, 0, :, : n - 1] = vs[:, :, keep]

    # same content, but with the cache over-allocated rows poisoned
    kc2, vc2 = kc.copy(), vc.copy()
    kc2[:, 0, :, n - 1 :] = 1e3
    vc2[:, 0, :, n - 1 :] = -1e3

    args = (
        jnp.full((CFG.n_layers, 1), n - 1, jnp.int32),
        jnp.asarray([n], jnp.int32),
        jnp.asarray([15], jnp.int32),
    )
    l1, *_ = M.decode_step(CFG, params, jnp.asarray(kc), jnp.asarray(vc), *args)
    l2, *_ = M.decode_step(CFG, params, jnp.asarray(kc2), jnp.asarray(vc2), *args)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)


def test_decode_batch_slots_independent(params):
    """Slot 0's output is unaffected by slot 1's content (batched decode)."""
    ids = [1, 8, 9, 10]
    _, ks, vs, _ = _prefill(params, ids)
    tmax = 64
    kc = np.zeros((CFG.n_layers, 2, CFG.n_kv_heads, tmax, CFG.d_head), np.float32)
    vc = np.zeros_like(kc)
    kc[:, 0, :, :4] = np.asarray(ks)[:, :, :4]
    vc[:, 0, :, :4] = np.asarray(vs)[:, :, :4]
    kcb = kc.copy()
    vcb = vc.copy()
    kcb[:, 1] = np.random.default_rng(5).standard_normal(kcb[:, 1].shape)

    def run(k, v, t1):
        lg, *_ = M.decode_step(
            CFG, params, jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(np.broadcast_to(np.array([4, 9], np.int32), (CFG.n_layers, 2)).copy()),
            jnp.asarray([4, 9], jnp.int32),
            jnp.asarray([10, t1], jnp.int32),
        )
        return np.asarray(lg)

    a = run(kc, vc, 11)
    b = run(kcb, vcb, 12)
    np.testing.assert_allclose(a[0], b[0], rtol=1e-5)


def test_rope_rotation_preserves_norm():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((5, 16)).astype(np.float32))
    cos, sin = M.rope_angles(CFG, jnp.arange(5))
    y = M.rope_apply(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_position_zero_identity():
    x = jnp.asarray(np.random.default_rng(1).standard_normal((1, 16)).astype(np.float32))
    cos, sin = M.rope_angles(CFG, jnp.zeros((1,)))
    y = M.rope_apply(x, cos, sin)
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)

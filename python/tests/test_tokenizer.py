"""Tokenizer round-trip and digit-segmentation properties (the Fig. 2
mechanism: llama-like packs 3 digits/token, qwen-like 1 digit/token)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import common as C
from compile import tokenizer as T


def test_vocab_layout():
    assert C.VOCAB[C.PAD] == "<pad>"
    assert C.VOCAB[C.DIGIT1_BASE] == "0"
    assert C.VOCAB[C.DIGIT1_BASE + 9] == "9"
    assert C.VOCAB[C.DIGIT2_BASE] == "00"
    assert C.VOCAB[C.DIGIT3_BASE] == "000"
    assert C.VOCAB[C.DIGIT3_BASE + 999] == "999"
    assert C.VOCAB[C.WORD_BASE] == "the"
    assert C.VOCAB_SIZE == C.WORD_BASE + len(C.WORDS)


def test_digit_run_lengths():
    qwen = T.Tokenizer(1)
    llama = T.Tokenizer(3)
    run = "1234567890" * 6 + "1234"  # 64 digits
    assert len(qwen.encode_digit_run(run)) == 64
    assert len(llama.encode_digit_run(run)) == 22  # ceil(64/3)


@settings(max_examples=200, deadline=None)
@given(st.text(alphabet="0123456789", min_size=1, max_size=80), st.sampled_from([1, 3]))
def test_digit_roundtrip(run, dpt):
    tok = T.Tokenizer(dpt)
    ids = tok.encode_digit_run(run)
    assert tok.decode_digits(ids) == run


@settings(max_examples=100, deadline=None)
@given(
    st.lists(
        st.one_of(
            st.sampled_from(C.WORDS),
            st.text(alphabet="0123456789", min_size=1, max_size=12),
        ),
        min_size=1,
        max_size=30,
    ),
    st.sampled_from([1, 3]),
)
def test_text_roundtrip(symbols, dpt):
    # Adjacent digit runs merge on decode (digit tokens concatenate), so the
    # canonical-text property only holds when digit runs are separated by
    # words; drop the second of any adjacent digit pair.
    canon = []
    for s in symbols:
        if s.isdigit() and canon and canon[-1].isdigit():
            continue
        canon.append(s)
    text = " ".join(canon)
    tok = T.Tokenizer(dpt)
    ids = tok.encode(text)
    assert tok.decode(ids) == text


def test_unknown_maps_to_unk():
    tok = T.Tokenizer(1)
    assert tok.encode("zzzznotaword") == [C.UNK]


def test_bos_prepended():
    tok = T.Tokenizer(1)
    assert tok.encode("the", bos=True)[0] == C.BOS

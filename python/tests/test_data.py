"""Workload-generator sanity: every family yields answerable prompts whose
answers are literally present in (or derivable from) the context."""

import numpy as np
import pytest

from compile import common as C
from compile import data as D
from compile import tokenizer as T


@pytest.mark.parametrize("fam", D.FAMILIES)
def test_family_generates(fam):
    rng = np.random.default_rng(0)
    for _ in range(5):
        if fam == "passkey":
            prompt, answer = D.gen_passkey(rng, n_filler=100, n_digits=64)
        else:
            prompt, answer = D.GENERATORS[fam](rng, n_filler=100)
        assert prompt.endswith("<a>")
        assert len(answer) > 0


def test_passkey_answer_in_context():
    rng = np.random.default_rng(1)
    prompt, answer = D.gen_passkey(rng, n_filler=50, n_digits=64)
    assert len(answer) == 64 and answer.isdigit()
    assert answer in prompt


def test_passkey_depth_controls_position():
    rng = np.random.default_rng(2)
    p0, a0 = D.gen_passkey(rng, n_filler=200, depth=0.0)
    rng = np.random.default_rng(2)
    p1, a1 = D.gen_passkey(rng, n_filler=200, depth=1.0)
    assert p0.split().index("pass") < p1.split().index("pass")


@pytest.mark.parametrize("fam", ["single_qa", "multi_qa", "synthetic", "code"])
def test_answer_tokens_present(fam):
    rng = np.random.default_rng(3)
    for _ in range(10):
        prompt, answer = D.GENERATORS[fam](rng, n_filler=80)
        for sym in answer.split():
            assert sym in prompt.split(), (fam, sym)


def test_summarization_coverage_order():
    rng = np.random.default_rng(4)
    prompt, answer = D.gen_summarization(rng, n_filler=120)
    vals = answer.split()
    body = prompt.split()
    positions = []
    for v in vals:
        # find "item <v>" occurrence
        for i in range(len(body) - 1):
            if body[i] == "item" and body[i + 1] == v:
                positions.append(i)
                break
    assert len(positions) == len(vals)
    assert positions == sorted(positions)


def test_fewshot_map_consistency():
    rng = np.random.default_rng(5)
    prompt, answer = D.gen_fewshot(rng, n_filler=60)
    # the queried word's mapping matches the deterministic pairing
    body = prompt.split()
    q_idx = len(body) - 2  # ... in: <w> out: <a>
    w = body[body.index("<q>") + 2]
    vals = D._VALUES
    assert answer == vals[D._fewshot_map(vals.index(w))]


def test_prompt_token_budget():
    """Generated prompts fit the model context after tokenization."""
    rng = np.random.default_rng(6)
    tok = T.for_variant("qwen_like")
    for _ in range(10):
        prompt, answer = D.sample_task(rng, n_filler=300)
        ids = tok.encode(prompt, bos=True)
        assert len(ids) < 640  # callers pick n_filler to bucket; sanity bound

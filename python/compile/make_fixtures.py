"""Emit the small hermetic golden fixtures for `cargo test`.

Unlike artifacts/golden/* (written by aot.py during `make artifacts`),
these vectors are tiny, checked into the repo at rust/tests/fixtures/, and
validated by rust/tests/golden.rs on every `cargo test` — no artifacts, no
XLA.  Inputs are quantized to 4 decimals so the JSON stays small and both
languages parse the exact same decimal strings (f64 -> f32 double-rounding
is identical on both sides).

Regenerate with:

    cd python && python -m compile.make_fixtures
"""

from __future__ import annotations

import json
import os

import numpy as np

from compile import tokenizer as T
from compile.kernels import ref as R

H = 2  # heads per fixture case
D = 8  # channels per head (small on purpose; oracles are shape-generic)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures")


def quantized(rng: np.random.Generator, shape, scale: float, offset: float) -> np.ndarray:
    x = rng.standard_normal(shape) * scale + offset
    return np.round(x, 4).astype(np.float32)


def emit_scores() -> None:
    rng = np.random.default_rng(20250727)
    cases = []
    for l in (4, 8, 16):
        shape = (H, l, D)
        kc = quantized(rng, shape, 1.0, 0.0)
        vc = quantized(rng, shape, 2.0, 1.0)
        kr = quantized(rng, shape, 0.5, -3.0)
        vr = quantized(rng, shape, 1.0, 0.0)
        cases.append(
            {
                "l": l,
                "k_cur": kc.ravel().tolist(),
                "v_cur": vc.ravel().tolist(),
                "k_ref": kr.ravel().tolist(),
                "v_ref": vr.ravel().tolist(),
                "lagkv": np.asarray(R.lagkv_scores_ref(kc, vc, kr, vr)).ravel().tolist(),
                "localkv": np.asarray(R.localkv_scores_ref(kc, vc)).ravel().tolist(),
                "l2norm": np.asarray(R.l2norm_scores_ref(kc)).ravel().tolist(),
            }
        )
    # adversarial: constant reference channels (EPS guard parity)
    l = 8
    kc = quantized(rng, (H, l, D), 1.0, 0.0)
    vc = quantized(rng, (H, l, D), 1.0, 0.0)
    kr = np.full((H, l, D), 2.5, np.float32)
    vr = np.full((H, l, D), -1.25, np.float32)
    cases.append(
        {
            "l": l,
            "k_cur": kc.ravel().tolist(),
            "v_cur": vc.ravel().tolist(),
            "k_ref": kr.ravel().tolist(),
            "v_ref": vr.ravel().tolist(),
            "lagkv": np.asarray(R.lagkv_scores_ref(kc, vc, kr, vr)).ravel().tolist(),
            "localkv": np.asarray(R.localkv_scores_ref(kc, vc)).ravel().tolist(),
            "l2norm": np.asarray(R.l2norm_scores_ref(kc)).ravel().tolist(),
        }
    )
    with open(os.path.join(OUT_DIR, "scores.json"), "w") as f:
        json.dump({"h": H, "d": D, "cases": cases}, f)


def emit_topk() -> None:
    rng = np.random.default_rng(7)
    scores = np.round(rng.standard_normal((3, 16)), 4).astype(np.float32)
    # row 2 carries deliberate ties: the earlier index must win
    scores[2, :] = np.float32(0.5)
    scores[2, 3] = np.float32(0.75)
    scores[2, 11] = np.float32(0.75)
    idx = np.asarray(R.topk_indices_ref(scores, 5))
    with open(os.path.join(OUT_DIR, "topk.json"), "w") as f:
        json.dump(
            {"scores": scores.ravel().tolist(), "k": 5, "idx": idx.ravel().tolist()}, f
        )


def emit_tokenizer() -> None:
    texts = [
        "the pass key is 1234567890 . remember it",
        "<q> pass key <a>",
        "code 42 is 87654321 .",
        "fact the falcon is crimson .",
        "<sep> pass key is 98765432109876543210 . remember it <sep>",
        "call def return ( ) : in: out: doc item",
        "unknownword 7 007 1 22 333 4444",
    ]
    tok_cases = {}
    for variant in ("llama_like", "qwen_like"):
        tok = T.for_variant(variant)
        tok_cases[variant] = [{"text": s, "ids": tok.encode(s, bos=False)} for s in texts]
    with open(os.path.join(OUT_DIR, "tokenizer.json"), "w") as f:
        json.dump(tok_cases, f)


def main() -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    emit_scores()
    emit_topk()
    emit_tokenizer()
    for name in ("scores.json", "topk.json", "tokenizer.json"):
        path = os.path.join(OUT_DIR, name)
        print(f"wrote {path} ({os.path.getsize(path)} bytes)")


if __name__ == "__main__":
    main()

"""Synthetic long-context task generators (training side).

Seven task families.  The first is the paper's 64-digit passkey retrieval;
the other six mirror the LongBench categories used in Table 1.  Every family
embeds its answer-critical span at a controlled depth inside filler text so
that KV-cache eviction policies are stressed exactly the way the paper's
benchmarks stress them.

The Rust crate re-implements these generators (rust/src/workloads/) with the
same templates; prompts are format-identical, so the build-time-trained
model is in-distribution at serve time.

All generators return ``(prompt, answer)`` as *text* (see tokenizer.py for
the text conventions).  ``filler(rng, n)`` produces ``n`` whitespace symbols
of haystack material.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from . import common as C

Task = Tuple[str, str]  # (prompt text, answer text)

FAMILIES = [
    "passkey",
    "single_qa",
    "multi_qa",
    "summarization",
    "fewshot",
    "synthetic",
    "code",
]

# Content-word helpers ---------------------------------------------------------

_NOUNS = C.CONTENT_WORDS[:48]
_VALUES = C.CONTENT_WORDS[48:]


def filler(rng: np.random.Generator, n_words: int) -> List[str]:
    """n_words of haystack filler, sentence-ish (period every 8..14 words)."""
    out: List[str] = []
    gap = int(rng.integers(8, 15))
    for i in range(n_words):
        out.append(C.FILLER_WORDS[int(rng.integers(0, len(C.FILLER_WORDS)))])
        gap -= 1
        if gap == 0:
            out.append(".")
            gap = int(rng.integers(8, 15))
    return out


def digits(rng: np.random.Generator, n: int) -> str:
    return "".join(str(int(rng.integers(0, 10))) for _ in range(n))


def _splice(hay: List[str], needle: List[str], depth: float) -> List[str]:
    """Insert needle at fractional depth of the haystack."""
    pos = int(round(depth * len(hay)))
    return hay[:pos] + needle + hay[pos:]


# -- 1. passkey (the paper's needle test) ---------------------------------------


def gen_passkey(
    rng: np.random.Generator,
    n_filler: int = 300,
    n_digits: int = 64,
    depth: float | None = None,
) -> Task:
    if depth is None:
        depth = float(rng.uniform(0.0, 1.0))
    key = digits(rng, n_digits)
    needle = ["<sep>", "pass", "key", "is", key, ".", "remember", "it", "<sep>"]
    hay = filler(rng, n_filler)
    body = _splice(hay, needle, depth)
    prompt = " ".join(body + ["<q>", "pass", "key", "<a>"])
    return prompt, key


# -- 2. single-doc QA ------------------------------------------------------------


def gen_single_qa(rng: np.random.Generator, n_filler: int = 300) -> Task:
    n_facts = int(rng.integers(3, 7))
    nouns = rng.choice(len(_NOUNS), size=n_facts, replace=False)
    vals = rng.integers(0, len(_VALUES), size=n_facts)
    hay = filler(rng, n_filler)
    for j in range(n_facts):
        fact = ["fact", "the", _NOUNS[int(nouns[j])], "is", _VALUES[int(vals[j])], "."]
        hay = _splice(hay, fact, float(rng.uniform(0.05, 0.95)))
    pick = int(rng.integers(0, n_facts))
    prompt = " ".join(hay + ["<q>", "the", _NOUNS[int(nouns[pick])], "<a>"])
    return prompt, _VALUES[int(vals[pick])]


# -- 3. multi-doc QA -------------------------------------------------------------


def gen_multi_qa(rng: np.random.Generator, n_filler: int = 300) -> Task:
    """Two facts in two <sep>-separated docs; answer both values in order."""
    nouns = rng.choice(len(_NOUNS), size=2, replace=False)
    vals = rng.integers(0, len(_VALUES), size=2)
    docs: List[str] = []
    per_doc = n_filler // 2
    for j in range(2):
        hay = filler(rng, per_doc)
        fact = ["fact", "the", _NOUNS[int(nouns[j])], "is", _VALUES[int(vals[j])], "."]
        docs += ["<sep>", "doc"] + _splice(hay, fact, float(rng.uniform(0.1, 0.9)))
    prompt = " ".join(
        docs
        + ["<q>", "the", _NOUNS[int(nouns[0])], "and", "the", _NOUNS[int(nouns[1])], "<a>"]
    )
    return prompt, f"{_VALUES[int(vals[0])]} {_VALUES[int(vals[1])]}"


# -- 4. summarization (salient-fact coverage) ------------------------------------


def gen_summarization(rng: np.random.Generator, n_filler: int = 300) -> Task:
    """k salient items must all be recalled, in order (coverage metric)."""
    k = int(rng.integers(2, 5))
    vals = rng.choice(len(_VALUES), size=k, replace=False)
    hay = filler(rng, n_filler)
    # insert in order at increasing depths so answer order is well-defined
    depths = np.sort(rng.uniform(0.05, 0.95, size=k))
    for j in range(k - 1, -1, -1):  # back-to-front keeps earlier depths valid
        item = ["item", _VALUES[int(vals[j])], "."]
        hay = _splice(hay, item, float(depths[j]))
    prompt = " ".join(hay + ["<q>", "summary", "<a>"])
    return prompt, " ".join(_VALUES[int(v)] for v in vals)


# -- 5. few-shot -----------------------------------------------------------------


def _fewshot_map(w_idx: int) -> int:
    """Deterministic pairing on the value table (fixed 'task' to learn)."""
    return (w_idx * 7 + 3) % len(_VALUES)


def gen_fewshot(rng: np.random.Generator, n_filler: int = 200) -> Task:
    n_shots = int(rng.integers(3, 6))
    idxs = rng.choice(len(_VALUES), size=n_shots + 1, replace=False)
    shots: List[str] = []
    for j in range(n_shots):
        w = int(idxs[j])
        shots += ["in:", _VALUES[w], "out:", _VALUES[_fewshot_map(w)], "."]
    hay = filler(rng, n_filler)
    body = _splice(hay, shots, float(rng.uniform(0.0, 0.6)))
    q = int(idxs[n_shots])
    prompt = " ".join(body + ["<q>", "in:", _VALUES[q], "out:", "<a>"])
    return prompt, _VALUES[_fewshot_map(q)]


# -- 6. synthetic (indexed code retrieval, PassageRetrieval-like) -----------------


def gen_synthetic(rng: np.random.Generator, n_filler: int = 300) -> Task:
    n_codes = int(rng.integers(3, 7))
    ids = rng.choice(90, size=n_codes, replace=False) + 10  # 2-digit indices
    codes = [digits(rng, 8) for _ in range(n_codes)]
    hay = filler(rng, n_filler)
    for j in range(n_codes):
        entry = ["code", str(int(ids[j])), "is", codes[j], "."]
        hay = _splice(hay, entry, float(rng.uniform(0.05, 0.95)))
    pick = int(rng.integers(0, n_codes))
    prompt = " ".join(hay + ["<q>", "code", str(int(ids[pick])), "<a>"])
    return prompt, codes[pick]


# -- 7. code (identifier recall) ---------------------------------------------------


def gen_code(rng: np.random.Generator, n_filler: int = 300) -> Task:
    n_defs = int(rng.integers(3, 7))
    names = rng.choice(len(_NOUNS), size=n_defs, replace=False)
    rets = rng.integers(0, len(_VALUES), size=n_defs)
    hay = filler(rng, n_filler)
    for j in range(n_defs):
        d = ["def", _NOUNS[int(names[j])], "(", ")", ":", "return", _VALUES[int(rets[j])]]
        hay = _splice(hay, d, float(rng.uniform(0.05, 0.95)))
    pick = int(rng.integers(0, n_defs))
    prompt = " ".join(hay + ["<q>", "call", _NOUNS[int(names[pick])], "<a>"])
    return prompt, _VALUES[int(rets[pick])]


GENERATORS = {
    "passkey": gen_passkey,
    "single_qa": gen_single_qa,
    "multi_qa": gen_multi_qa,
    "summarization": gen_summarization,
    "fewshot": gen_fewshot,
    "synthetic": gen_synthetic,
    "code": gen_code,
}


def sample_task(rng: np.random.Generator, n_filler: int) -> Task:
    """Training mixture.  Passkey (the headline benchmark) is upweighted to
    ~1/3; the remaining mass is uniform over the LongBench-like families."""
    if rng.uniform() < 0.34:
        nd = int(rng.integers(4, 73))
        return gen_passkey(rng, n_filler=n_filler, n_digits=nd)
    fam = FAMILIES[1 + int(rng.integers(0, len(FAMILIES) - 1))]
    return GENERATORS[fam](rng, n_filler=n_filler)

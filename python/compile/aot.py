"""AOT lowering: JAX entry points -> HLO *text* artifacts for the Rust
runtime.

HLO text (NOT ``lowered.compile().serialize()`` / proto bytes) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the image's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).  Everything is lowered with
``return_tuple=True`` so the Rust side unwraps with ``to_tuple*``.

Exports (artifacts/hlo/):
  prefill_t{T}.hlo.txt        T in PREFILL_BUCKETS
  decode_b{B}.hlo.txt         B in DECODE_BUCKETS (Tmax = cfg.max_seq)
  lagkv_score_l{L}.hlo.txt    L in SCORE_LAGS  (the L1 Pallas kernel)
  l2norm_score_l{L}.hlo.txt
  decode_attn.hlo.txt         standalone Pallas decode-attention kernel

plus artifacts/manifest.json (shapes, param order, bucket inventory) and
artifacts/golden/*.json (reference vectors for the Rust unit tests).

Weights are HLO *parameters*, so the same HLO serves both model variants.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import common as C
from . import model as M
from . import tokenizer as T
from .kernels import attention as AK
from .kernels import lagkv_score as LS
from .kernels import ref as R

PREFILL_BUCKETS = [128, 256, 512]
DECODE_BUCKETS = [1, 4]
SCORE_LAGS = [8, 16, 32, 64, 128]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default HLO printer ELIDES large constants as
    # `constant({...})`, which the text parser silently replaces with
    # garbage values — the folded RoPE frequency table came back as
    # denormals and scrambled every position > 0.  Print with
    # print_large_constants so the text round-trips faithfully.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # ... and the modern printer's source-location metadata uses attributes
    # (source_end_line etc.) the 0.5.1-era parser rejects — strip it.
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs(cfg):
    return [spec(s) for s in M.param_shapes(cfg).values()]


# -- entry-point wrappers (flat positional args for a stable ABI) ---------------


def prefill_flat(cfg, *args):
    params = M.params_from_list(args[: len(M.PARAM_ORDER)])
    tokens, true_len = args[len(M.PARAM_ORDER) :]
    return M.prefill(cfg, params, tokens, true_len)


def decode_flat(cfg, *args):
    params = M.params_from_list(args[: len(M.PARAM_ORDER)])
    k, v, lens, pos, token = args[len(M.PARAM_ORDER) :]
    return M.decode_step(cfg, params, k, v, lens, pos, token)


def lower_entry(fn, arg_specs):
    return to_hlo_text(jax.jit(fn).lower(*arg_specs))


def export_all(cfg: C.ModelConfig, hlo_dir: str) -> dict:
    os.makedirs(hlo_dir, exist_ok=True)
    nl, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    tmax = cfg.max_seq
    manifest = {
        "model_config": json.loads(cfg.to_json()),
        "param_order": M.PARAM_ORDER,
        "param_shapes": {k: list(v) for k, v in M.param_shapes(cfg).items()},
        "prefill_buckets": PREFILL_BUCKETS,
        "decode_buckets": DECODE_BUCKETS,
        "score_lags": SCORE_LAGS,
        "tmax": tmax,
        "entries": {},
    }

    def emit(name, fn, arg_specs, outputs):
        path = os.path.join(hlo_dir, f"{name}.hlo.txt")
        text = lower_entry(fn, arg_specs)
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": f"hlo/{name}.hlo.txt",
            "args": [[list(s.shape), str(s.dtype)] for s in arg_specs],
            "outputs": outputs,
        }
        print(f"  wrote {path} ({len(text) / 1024:.0f} KiB)", flush=True)

    for t in PREFILL_BUCKETS:
        emit(
            f"prefill_t{t}",
            functools.partial(prefill_flat, cfg),
            param_specs(cfg) + [spec((t,), jnp.int32), spec((), jnp.int32)],
            ["logits_last[V]", "k[nl,hkv,T,dh]", "v[nl,hkv,T,dh]", "attn_sums[nl,hkv,T]"],
        )

    for b in DECODE_BUCKETS:
        emit(
            f"decode_b{b}",
            functools.partial(decode_flat, cfg),
            param_specs(cfg)
            + [
                spec((nl, b, hkv, tmax, dh)),
                spec((nl, b, hkv, tmax, dh)),
                spec((nl, b), jnp.int32),
                spec((b,), jnp.int32),
                spec((b,), jnp.int32),
            ],
            [
                "logits[B,V]",
                "k_new[nl,B,hkv,dh]",
                "v_new[nl,B,hkv,dh]",
                "k_out[nl,B,hkv,Tmax,dh]",
                "v_out[nl,B,hkv,Tmax,dh]",
                "attn_row[nl,B,hkv,Tmax]",
            ],
        )

    for l in SCORE_LAGS:
        shp = spec((hkv, l, dh))
        emit(
            f"lagkv_score_l{l}",
            lambda kc, vc, kr, vr: (LS.lagkv_scores(kc, vc, kr, vr),),
            [shp, shp, shp, shp],
            ["scores[H,L]"],
        )
        emit(
            f"l2norm_score_l{l}",
            lambda kc: (LS.l2norm_scores(kc),),
            [shp],
            ["scores[H,L]"],
        )

    emit(
        "decode_attn",
        lambda q, k, v, ln: (AK.decode_attention(q, k, v, ln, blk=64),),
        [
            spec((cfg.n_q_heads, dh)),
            spec((hkv, tmax, dh)),
            spec((hkv, tmax, dh)),
            spec((), jnp.int32),
        ],
        ["out[Hq,D]"],
    )
    return manifest


# -- golden vectors for the Rust unit tests -------------------------------------


def export_goldens(cfg: C.ModelConfig, golden_dir: str) -> None:
    os.makedirs(golden_dir, exist_ok=True)
    rng = np.random.default_rng(42)

    # 1. LagKV / LocalKV / L2 scores on random K/V partitions.
    cases = []
    for l in (8, 16):
        shape = (cfg.n_kv_heads, l, cfg.d_head)
        kc, vc, kr, vr = (
            rng.standard_normal(shape).astype(np.float32) * s + o
            for s, o in ((1, 0), (2, 1), (0.5, -3), (1, 0))
        )
        cases.append(
            {
                "l": l,
                "k_cur": kc.ravel().tolist(),
                "v_cur": vc.ravel().tolist(),
                "k_ref": kr.ravel().tolist(),
                "v_ref": vr.ravel().tolist(),
                "lagkv": np.asarray(R.lagkv_scores_ref(kc, vc, kr, vr)).ravel().tolist(),
                "localkv": np.asarray(R.localkv_scores_ref(kc, vc)).ravel().tolist(),
                "l2norm": np.asarray(R.l2norm_scores_ref(kc)).ravel().tolist(),
            }
        )
    with open(os.path.join(golden_dir, "scores.json"), "w") as f:
        json.dump({"h": cfg.n_kv_heads, "d": cfg.d_head, "cases": cases}, f)

    # 2. Tokenizer round-trips per variant.
    texts = [
        "the pass key is 1234567890 . remember it",
        "<q> pass key <a>",
        "code 42 is 87654321 .",
        "fact the falcon is crimson .",
    ]
    tok_cases = {}
    for variant in C.MODEL_VARIANTS:
        tok = T.for_variant(variant)
        tok_cases[variant] = [
            {"text": s, "ids": tok.encode(s, bos=False)} for s in texts
        ]
    with open(os.path.join(golden_dir, "tokenizer.json"), "w") as f:
        json.dump(tok_cases, f)

    # 3. Top-k selection convention.
    scores = rng.standard_normal((cfg.n_kv_heads, 16)).astype(np.float32)
    idx = np.asarray(R.topk_indices_ref(scores, 5))
    with open(os.path.join(golden_dir, "topk.json"), "w") as f:
        json.dump({"scores": scores.ravel().tolist(), "k": 5, "idx": idx.ravel().tolist()}, f)


def export_model_goldens(cfg: C.ModelConfig, art_dir: str) -> None:
    """End-to-end goldens on the TRAINED llama_like weights: prefill logits +
    3 greedy decode tokens for a fixed prompt.  The Rust integration test
    replays these through the compiled HLO."""
    wpath = os.path.join(art_dir, "models", "llama_like", "weights.npz")
    if not os.path.exists(wpath):
        print("  (skip model goldens: no trained weights yet)")
        return
    raw = np.load(wpath)
    params = {k: jnp.asarray(raw[k]) for k in M.PARAM_ORDER}
    tok = T.for_variant("llama_like")
    prompt = "fact the falcon is crimson . <q> the falcon <a>"
    ids = tok.encode(prompt, bos=True)
    t = 128
    tokens = np.full((t,), C.PAD, np.int32)
    tokens[: len(ids)] = ids
    logits, ks, vs, sums = M.prefill(cfg, params, jnp.asarray(tokens), len(ids))

    # 3 greedy decode steps through decode_step (batch 1)
    tmax = cfg.max_seq
    nl, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    kc = np.zeros((nl, 1, hkv, tmax, dh), np.float32)
    vc = np.zeros((nl, 1, hkv, tmax, dh), np.float32)
    kc[:, 0, :, : len(ids)] = np.asarray(ks)[:, :, : len(ids)]
    vc[:, 0, :, : len(ids)] = np.asarray(vs)[:, :, : len(ids)]
    lens = np.full((nl, 1), len(ids), np.int32)
    pos = np.array([len(ids)], np.int32)
    token = np.array([int(np.asarray(logits).argmax())], np.int32)
    out_tokens = [int(token[0])]
    all_logits = [np.asarray(logits)]
    for _ in range(3):
        lg, kn, vn, kc, vc, row = M.decode_step(
            cfg, params, jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(lens), jnp.asarray(pos), jnp.asarray(token)
        )
        kc, vc = np.asarray(kc), np.asarray(vc)
        nxt = int(np.asarray(lg)[0].argmax())
        out_tokens.append(nxt)
        all_logits.append(np.asarray(lg)[0])
        lens = lens + 1
        pos = pos + 1
        token = np.array([nxt], np.int32)
    with open(os.path.join(art_dir, "golden", "model_e2e.json"), "w") as f:
        json.dump(
            {
                "prompt": prompt,
                "prompt_ids": [int(i) for i in ids],
                "prefill_bucket": t,
                "greedy_tokens": out_tokens,
                "logits_first5": [l[:5].tolist() for l in all_logits],
            },
            f,
        )
    print(f"  wrote model_e2e.json (greedy tokens: {out_tokens})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-goldens", action="store_true")
    args = ap.parse_args()
    art = args.out
    cfg = C.ModelConfig()
    manifest = export_all(cfg, os.path.join(art, "hlo"))
    with open(os.path.join(art, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if not args.skip_goldens:
        export_goldens(cfg, os.path.join(art, "golden"))
        export_model_goldens(cfg, art)
    print("aot export complete")


if __name__ == "__main__":
    main()

"""L2: tiny GQA transformer in JAX — the compute graph the Rust coordinator
drives through AOT-compiled HLO.

Architecture mirrors Llama-3/Qwen-2.5 (the paper's base models) at 1/1000
scale: RoPE, RMSNorm, SwiGLU, grouped-query attention.  Entry points that
are AOT-lowered (aot.py):

* ``prefill``      — full-prompt forward; returns the last-position logits,
                     the per-layer KV cache, and per-token accumulated
                     attention mass (the H2O baseline's food — produced by
                     the *instrumented* path the paper argues real serving
                     stacks cannot afford).
* ``decode_step``  — one autoregressive step over a compacted,
                     over-allocated KV cache with valid-length masking;
                     appends in-graph (dynamic_update_slice) so the cache
                     can stay device-resident across steps (§Perf).
* ``lagkv_score_graph`` — wraps the L1 Pallas kernel so it lowers into its
                     own HLO artifact.

Weights are *parameters* of the lowered HLO, so one HLO set serves both
trained model variants (llama_like / qwen_like): the Rust runtime feeds a
different ``weights.npz`` per variant.

RoPE is applied to K at *write* position, so evicting cache rows never
perturbs the positional geometry of the survivors — the property that makes
token eviction position-stable.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import ModelConfig

Params = Dict[str, jax.Array]

# Flat, ordered parameter list — the AOT calling convention shared with the
# Rust runtime (recorded in artifacts/manifest.json as well).
PARAM_ORDER: List[str] = [
    "emb",
    "wq",
    "wk",
    "wv",
    "wo",
    "w_gate",
    "w_up",
    "w_down",
    "ln1",
    "ln2",
    "ln_f",
    "lm_head",
]

_STACKED = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "ln1", "ln2")


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    nl, d, f = cfg.n_layers, cfg.d_model, cfg.d_ff
    hq, hkv, dh = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head
    return {
        "emb": (cfg.vocab_size, d),
        "wq": (nl, d, hq * dh),
        "wk": (nl, d, hkv * dh),
        "wv": (nl, d, hkv * dh),
        "wo": (nl, hq * dh, d),
        "w_gate": (nl, d, f),
        "w_up": (nl, d, f),
        "w_down": (nl, f, d),
        "ln1": (nl, d),
        "ln2": (nl, d),
        "ln_f": (d,),
        "lm_head": (d, cfg.vocab_size),
    }


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    rng = np.random.default_rng(seed)
    out: Params = {}
    for name, shape in param_shapes(cfg).items():
        if name.startswith("ln"):
            out[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            w = rng.standard_normal(shape, dtype=np.float32) / np.sqrt(fan_in)
            out[name] = jnp.asarray(w)
    return out


def params_to_list(params: Params) -> List[jax.Array]:
    return [params[n] for n in PARAM_ORDER]


def params_from_list(flat) -> Params:
    return dict(zip(PARAM_ORDER, flat))


# -- building blocks ----------------------------------------------------------


def rmsnorm(x, g, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def rope_angles(cfg: ModelConfig, positions):
    """positions [...,] -> (cos, sin) of shape [..., D/2]."""
    dh = cfg.d_head
    inv = cfg.rope_theta ** (-jnp.arange(0, dh, 2, dtype=jnp.float32) / dh)
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def rope_apply(x, cos, sin):
    """x: [..., D] with interleaved pairs; cos/sin broadcastable [..., D/2]."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


def swiglu(x, wg, wu, wd):
    return (jax.nn.silu(x @ wg) * (x @ wu)) @ wd


# -- prefill ------------------------------------------------------------------


def prefill(cfg: ModelConfig, params: Params, tokens, true_len):
    """Full-prompt forward.

    Args:
      tokens: [T] int32 (padded to the bucket length with <pad>).
      true_len: scalar int32, number of valid prompt tokens.
    Returns:
      logits_last: [V] logits at position true_len-1.
      k_cache, v_cache: [nl, Hkv, T, D] (RoPE-rotated keys; rows >= true_len
        are garbage the coordinator never reads).
      attn_sums: [nl, Hkv, T] — column sums of attention probability over
        valid query rows, aggregated over each KV group (H2O's statistic).
    """
    t = tokens.shape[0]
    hq, hkv, dh = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head
    group = hq // hkv

    x = params["emb"][tokens]  # [T, d]
    pos = jnp.arange(t)
    cos, sin = rope_angles(cfg, pos)  # [T, D/2]
    row_valid = pos < true_len
    causal = pos[None, :] <= pos[:, None]  # key j visible to query i
    col_valid = row_valid[None, :]

    def layer(x, w):
        xn = rmsnorm(x, w["ln1"], cfg.norm_eps)
        q = (xn @ w["wq"]).reshape(t, hq, dh)
        k = (xn @ w["wk"]).reshape(t, hkv, dh)
        v = (xn @ w["wv"]).reshape(t, hkv, dh)
        q = rope_apply(q, cos[:, None, :], sin[:, None, :])
        k = rope_apply(k, cos[:, None, :], sin[:, None, :])
        kg = jnp.repeat(k, group, axis=1)  # [T, Hq, D]
        vg = jnp.repeat(v, group, axis=1)
        s = jnp.einsum("thd,shd->hts", q, kg) / jnp.sqrt(jnp.float32(dh))
        mask = (causal & col_valid)[None]
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        p = p * mask
        attn = jnp.einsum("hts,shd->thd", p, vg).reshape(t, hq * dh)
        x = x + attn @ w["wo"]
        xn2 = rmsnorm(x, w["ln2"], cfg.norm_eps)
        x = x + swiglu(xn2, w["w_gate"], w["w_up"], w["w_down"])
        # H2O statistic: attention mass received by each key position from
        # valid queries, summed over the group's query heads.
        pv = p * row_valid[None, :, None]
        sums = pv.sum(axis=1).reshape(hkv, group, t).sum(axis=1)  # [Hkv, T]
        return x, (k.transpose(1, 0, 2), v.transpose(1, 0, 2), sums)

    stacked = {n: params[n] for n in _STACKED}
    x, (ks, vs, sums) = jax.lax.scan(layer, x, stacked)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits_last = x[true_len - 1] @ params["lm_head"]
    return logits_last, ks, vs, sums


# -- training forward (batched, full logits) -----------------------------------


def batched_logits(cfg: ModelConfig, params: Params, tokens):
    """[B, T] tokens -> [B, T, V] logits (causal; training batches are
    packed, padding handled by the loss mask)."""
    b, t = tokens.shape
    hq, hkv, dh = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head
    group = hq // hkv
    x = params["emb"][tokens]  # [B, T, d]
    pos = jnp.arange(t)
    cos, sin = rope_angles(cfg, pos)
    causal = pos[None, :] <= pos[:, None]

    def layer(x, w):
        xn = rmsnorm(x, w["ln1"], cfg.norm_eps)
        q = (xn @ w["wq"]).reshape(b, t, hq, dh)
        k = (xn @ w["wk"]).reshape(b, t, hkv, dh)
        v = (xn @ w["wv"]).reshape(b, t, hkv, dh)
        q = rope_apply(q, cos[:, None, :], sin[:, None, :])
        k = rope_apply(k, cos[:, None, :], sin[:, None, :])
        kg = jnp.repeat(k, group, axis=2)
        vg = jnp.repeat(v, group, axis=2)
        s = jnp.einsum("bthd,bshd->bhts", q, kg) / jnp.sqrt(jnp.float32(dh))
        s = jnp.where(causal[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("bhts,bshd->bthd", p, vg).reshape(b, t, hq * dh)
        x = x + attn @ w["wo"]
        xn2 = rmsnorm(x, w["ln2"], cfg.norm_eps)
        x = x + swiglu(xn2, w["w_gate"], w["w_up"], w["w_down"])
        return x, None

    stacked = {n: params[n] for n in _STACKED}
    x, _ = jax.lax.scan(layer, x, stacked)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return x @ params["lm_head"]


# -- decode -------------------------------------------------------------------


def decode_step(cfg: ModelConfig, params: Params, k_cache, v_cache, lens, pos, token):
    """One autoregressive step for a batch of B slots.

    Args:
      k_cache, v_cache: [nl, B, Hkv, Tmax, D] compacted caches (device-
        resident across steps on the fast path).
      lens:  [nl, B] int32 — valid cache rows per layer and slot.  Uniform
        across heads by construction of the compactor, but NOT across
        layers: the recursive-L2 variant (Appendix A.2) exempts the first
        two layers from compression, so their caches stay longer.  Idle
        slots use 0.
      pos:   [B] int32 — absolute position of `token` (RoPE phase).
      token: [B] int32 — the token to embed and append.
    Returns:
      logits:  [B, V]
      k_new, v_new: [nl, B, Hkv, D]  (for the coordinator's host mirror)
      k_out, v_out: [nl, B, Hkv, Tmax, D]  (in-graph appended caches)
      attn_row: [nl, B, Hkv, Tmax] — this step's attention mass per cache
        row, group-aggregated (H2O's decode-time statistic).
    """
    b = token.shape[0]
    hq, hkv, dh = cfg.n_q_heads, cfg.n_kv_heads, cfg.d_head
    group = hq // hkv
    tmax = k_cache.shape[3]

    x = params["emb"][token]  # [B, d]
    cos, sin = rope_angles(cfg, pos)  # [B, D/2]

    def layer(x, w_and_cache):
        w, kc, vc, lens_l = w_and_cache  # kc/vc: [B, Hkv, Tmax, D]; lens_l: [B]
        xn = rmsnorm(x, w["ln1"], cfg.norm_eps)
        q = (xn @ w["wq"]).reshape(b, hq, dh)
        k = (xn @ w["wk"]).reshape(b, hkv, dh)
        v = (xn @ w["wv"]).reshape(b, hkv, dh)
        q = rope_apply(q, cos[:, None, :], sin[:, None, :])
        k = rope_apply(k, cos[:, None, :], sin[:, None, :])

        # In-graph append at lens_l[b] (same row for every head).
        def upd(cache_b, new_b, len_b):
            return jax.lax.dynamic_update_slice(
                cache_b, new_b[:, None, :], (0, len_b, 0)
            )

        kc = jax.vmap(upd)(kc, k, lens_l)
        vc = jax.vmap(upd)(vc, v, lens_l)

        kg = jnp.repeat(kc, group, axis=1)  # [B, Hq, Tmax, D]
        vg = jnp.repeat(vc, group, axis=1)
        s = jnp.einsum("bhd,bhtd->bht", q, kg) / jnp.sqrt(jnp.float32(dh))
        valid = jnp.arange(tmax)[None, None, :] < (lens_l + 1)[:, None, None]
        s = jnp.where(valid, s, -1e30)
        p = jax.nn.softmax(s, axis=-1) * valid
        attn = jnp.einsum("bht,bhtd->bhd", p, vg).reshape(b, hq * dh)
        x = x + attn @ w["wo"]
        xn2 = rmsnorm(x, w["ln2"], cfg.norm_eps)
        x = x + swiglu(xn2, w["w_gate"], w["w_up"], w["w_down"])
        row = p.reshape(b, hkv, group, tmax).sum(axis=2)  # [B, Hkv, Tmax]
        return x, (k, v, kc, vc, row)

    stacked = {n: params[n] for n in _STACKED}
    x, (k_new, v_new, k_out, v_out, rows) = jax.lax.scan(
        layer, x, (stacked, k_cache, v_cache, lens)
    )
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return logits, k_new, v_new, k_out, v_out, rows


# -- LagKV score graph (L2 wrapper over the L1 Pallas kernel) -------------------


def lagkv_score_graph(k_cur, v_cur, k_ref, v_ref):
    """Thin L2 entry point so the L1 kernel lowers into its own HLO artifact
    the Rust cache manager can invoke (``--scorer=xla``)."""
    from .kernels import lagkv_score

    return (lagkv_score.lagkv_scores(k_cur, v_cur, k_ref, v_ref),)

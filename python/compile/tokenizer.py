"""Whitespace + digit-run tokenizers, mirrored in rust/src/tokenizer/.

Text is a space-separated stream of symbols.  A symbol consisting purely of
ASCII digits is a *digit run* and is segmented according to the tokenizer
mode:

* ``digits_per_token=1`` ("qwen-like"): one token per digit.
* ``digits_per_token=3`` ("llama-like"): greedy 3-digit packing from the
  left; a remainder of 2 or 1 digits uses the 2-digit / 1-digit slices.

Any other symbol is looked up in the word table, falling back to ``<unk>``.
Decoding inverts the mapping; digit tokens are concatenated without spaces
when adjacent, so ``decode(encode(s)) == s`` for canonical inputs (tested).
"""

from __future__ import annotations

from typing import List

from . import common as C


class Tokenizer:
    def __init__(self, digits_per_token: int):
        assert digits_per_token in (1, 3)
        self.digits_per_token = digits_per_token

    # -- encode ---------------------------------------------------------------

    def encode_digit_run(self, run: str) -> List[int]:
        """Segment a run of digits into token ids."""
        assert run.isdigit()
        out: List[int] = []
        if self.digits_per_token == 1:
            for ch in run:
                out.append(C.DIGIT1_BASE + int(ch))
            return out
        i = 0
        n = len(run)
        while i < n:
            rem = n - i
            if rem >= 3:
                out.append(C.DIGIT3_BASE + int(run[i : i + 3]))
                i += 3
            elif rem == 2:
                out.append(C.DIGIT2_BASE + int(run[i : i + 2]))
                i += 2
            else:
                out.append(C.DIGIT1_BASE + int(run[i]))
                i += 1
        return out

    def encode_symbol(self, sym: str) -> List[int]:
        if sym.isdigit():
            return self.encode_digit_run(sym)
        return [C.TOKEN_TO_ID.get(sym, C.UNK)]

    def encode(self, text: str, bos: bool = False) -> List[int]:
        ids: List[int] = [C.BOS] if bos else []
        for sym in text.split():
            ids.extend(self.encode_symbol(sym))
        return ids

    # -- decode ---------------------------------------------------------------

    @staticmethod
    def is_digit_token(tid: int) -> bool:
        return C.DIGIT1_BASE <= tid < C.WORD_BASE

    def decode(self, ids: List[int]) -> str:
        parts: List[str] = []
        prev_digit = False
        for tid in ids:
            if tid < 0 or tid >= C.VOCAB_SIZE:
                surf, is_digit = "<unk>", False
            else:
                surf = C.VOCAB[tid]
                is_digit = self.is_digit_token(tid)
            if is_digit and prev_digit:
                parts[-1] = parts[-1] + surf  # merge adjacent digit tokens
            else:
                parts.append(surf)
            prev_digit = is_digit
        return " ".join(parts)

    def decode_digits(self, ids: List[int]) -> str:
        """Concatenate the digit content of a token stream (for scoring)."""
        out = []
        for tid in ids:
            if self.is_digit_token(tid):
                out.append(C.VOCAB[tid])
        return "".join(out)


def for_variant(variant: str) -> Tokenizer:
    return Tokenizer(C.MODEL_VARIANTS[variant]["digits_per_token"])

"""Build-time training of the two tiny model variants.

This is the repo's substitute for the paper's Llama-3.1-8B / Qwen2.5-7B
checkpoints (DESIGN.md §2): each variant is trained from scratch on the
synthetic long-context retrieval mixture (data.py) using the tokenizer mode
that gives it the paper-relevant property — 3 digits/token ("llama_like")
vs 1 digit/token ("qwen_like").

Loss: next-token cross-entropy, answer tokens weighted 1.0 and context
tokens 0.1 (retrieval ability is what the benchmarks stress).  Optimizer:
hand-rolled Adam (no optax in the image).  A short curriculum moves from
seq 256 to the full context window.

Run via ``make artifacts``; steps tunable through LAGKV_TRAIN_STEPS
(default 300) so CI-ish runs can shrink the budget.

Outputs per variant under artifacts/models/<variant>/:
  weights.npz  config.json  vocab.json  train_log.json
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import common as C
from . import data as D
from . import model as M
from . import tokenizer as T

ANSWER_WEIGHT = 1.0
CONTEXT_WEIGHT = 0.1


# -- batch construction ---------------------------------------------------------


def build_example(
    rng: np.random.Generator, tok: T.Tokenizer, seq_len: int
) -> Tuple[np.ndarray, np.ndarray]:
    """One packed training row: [T] tokens, [T] per-position loss weights.

    Layout: <bos> prompt <a-part...> answer <eos> <pad>...; weights are for
    the *target* at each position (next-token convention handled by the
    caller's shift).
    """
    # pick filler size so prompt+answer fits seq_len with headroom
    n_filler = max(20, int(seq_len * 0.72))
    while True:
        prompt, answer = D.sample_task(rng, n_filler)
        p_ids = tok.encode(prompt, bos=True)
        a_ids = tok.encode(answer) + [C.EOS]
        if len(p_ids) + len(a_ids) <= seq_len:
            break
        n_filler = int(n_filler * 0.8)
    ids = p_ids + a_ids
    w = [CONTEXT_WEIGHT] * len(p_ids) + [ANSWER_WEIGHT] * len(a_ids)
    pad = seq_len - len(ids)
    tokens = np.array(ids + [C.PAD] * pad, dtype=np.int32)
    weights = np.array(w + [0.0] * pad, dtype=np.float32)
    return tokens, weights


def build_batch(rng, tok, batch, seq_len):
    toks = np.zeros((batch, seq_len), np.int32)
    ws = np.zeros((batch, seq_len), np.float32)
    for i in range(batch):
        toks[i], ws[i] = build_example(rng, tok, seq_len)
    return toks, ws


# -- loss / adam ---------------------------------------------------------------


def loss_fn(cfg: C.ModelConfig, params, tokens, weights):
    logits = M.batched_logits(cfg, params, tokens)  # [B, T, V]
    # next-token prediction: logits[:, :-1] predict tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    w = weights[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return (nll * w).sum() / jnp.maximum(w.sum(), 1.0)


def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.98, eps=1e-9, clip=1.0):
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)) + 1e-12
    )
    scale = jnp.minimum(1.0, clip / gnorm)
    grads = jax.tree.map(lambda g: g * scale, grads)
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda m_: m_ / (1 - b1 ** t.astype(jnp.float32)), m)
    vhat = jax.tree.map(lambda v_: v_ / (1 - b2 ** t.astype(jnp.float32)), v)
    new = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return new, {"m": m, "v": v, "t": t}


@functools.partial(jax.jit, static_argnames=("cfg",))
def train_step(cfg, params, opt, tokens, weights, lr):
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens, weights))(params)
    params, opt = adam_update(params, grads, opt, lr)
    return params, opt, loss


# -- teacher-forced answer accuracy (training progress signal) -------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def answer_accuracy(cfg, params, tokens, weights):
    logits = M.batched_logits(cfg, params, tokens)
    pred = logits[:, :-1].argmax(-1)
    tgt = tokens[:, 1:]
    mask = weights[:, 1:] >= ANSWER_WEIGHT
    correct = ((pred == tgt) & mask).sum()
    return correct / jnp.maximum(mask.sum(), 1)


# -- main -----------------------------------------------------------------------


def default_curriculum(total_steps: int, max_seq: int) -> List[Dict]:
    """(seq_len, batch, steps, lr) schedule; ~60% short, 40% full-window."""
    s1 = int(total_steps * 0.6)
    s2 = total_steps - s1
    return [
        {"seq": min(256, max_seq), "batch": 8, "steps": s1, "lr": 1e-3},
        {"seq": max_seq, "batch": 4, "steps": s2, "lr": 5e-4},
    ]


def greedy_passkey_eval(cfg, params, tok, n=8, n_digits=64, seed=123):
    """True generative eval: prefill + decode loop, partial-match score."""
    from . import data as D

    rng = np.random.default_rng(seed)
    nl, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    tmax = cfg.max_seq
    prefill_j = jax.jit(functools.partial(M.prefill, cfg))
    decode_j = jax.jit(functools.partial(M.decode_step, cfg))
    scores = []
    for _ in range(n):
        n_filler = 220 if tok.digits_per_token == 3 else 190
        prompt, key = D.gen_passkey(rng, n_filler=n_filler, n_digits=n_digits)
        ids = tok.encode(prompt, bos=True)
        if len(ids) > tmax - n_digits - 8:
            ids = ids[: tmax - n_digits - 8]
        bucket = tmax
        tokens = np.full((bucket,), C.PAD, np.int32)
        tokens[: len(ids)] = ids
        logits, ks, vs, _ = prefill_j(params, jnp.asarray(tokens), len(ids))
        kc = np.zeros((nl, 1, hkv, tmax, dh), np.float32)
        vc = np.zeros_like(kc)
        kc[:, 0, :, : len(ids)] = np.asarray(ks)[:, :, : len(ids)]
        vc[:, 0, :, : len(ids)] = np.asarray(vs)[:, :, : len(ids)]
        kc, vc = jnp.asarray(kc), jnp.asarray(vc)
        lens = jnp.full((nl, 1), len(ids), jnp.int32)
        pos = jnp.asarray([len(ids)], jnp.int32)
        token = int(np.asarray(logits).argmax())
        out = [token]
        max_new = n_digits + 6
        for _ in range(max_new):
            if token == C.EOS:
                break
            lg, kn, vn, kc, vc, _ = decode_j(
                params, kc, vc, lens, pos, jnp.asarray([token], jnp.int32)
            )
            token = int(np.asarray(lg)[0].argmax())
            out.append(token)
            lens = lens + 1
            pos = pos + 1
        pred = tok.decode_digits([t for t in out if t != C.EOS])
        # partial match: fraction of aligned leading digits (benchmark-style)
        match = sum(1 for a, b in zip(pred, key) if a == b) / len(key)
        scores.append(match)
    return float(np.mean(scores))


def train_variant(
    variant: str,
    out_dir: str,
    total_steps: int,
    seed: int = 0,
    log_every: int = 25,
    resume: bool = False,
) -> Dict:
    cfg = C.ModelConfig(name=variant)
    tok = T.for_variant(variant)
    rng = np.random.default_rng(seed + hash(variant) % 1000)
    wpath = os.path.join(out_dir, "weights.npz")
    if resume and os.path.exists(wpath):
        raw = np.load(wpath)
        params = {k: jnp.asarray(raw[k]) for k in M.PARAM_ORDER}
        print(f"[{variant}] resumed from {wpath}", flush=True)
    else:
        params = M.init_params(cfg, seed=seed)
    opt = adam_init(params)
    log: List[Dict] = []
    t0 = time.time()
    step = 0
    for phase in default_curriculum(total_steps, cfg.max_seq):
        for _ in range(phase["steps"]):
            tokens, weights = build_batch(rng, tok, phase["batch"], phase["seq"])
            params, opt, loss = train_step(
                cfg, params, opt, jnp.asarray(tokens), jnp.asarray(weights), phase["lr"]
            )
            if step % log_every == 0 or step == total_steps - 1:
                acc = answer_accuracy(cfg, params, jnp.asarray(tokens), jnp.asarray(weights))
                entry = {
                    "step": step,
                    "seq": phase["seq"],
                    "loss": float(loss),
                    "answer_acc": float(acc),
                    "elapsed_s": round(time.time() - t0, 1),
                }
                log.append(entry)
                print(f"[{variant}] {entry}", flush=True)
            step += 1

    needle = greedy_passkey_eval(cfg, params, tok)
    print(f"[{variant}] greedy 64-digit passkey partial-match: {needle:.3f}", flush=True)
    log.append({"step": step, "needle_partial": needle})

    os.makedirs(out_dir, exist_ok=True)
    np.savez(
        os.path.join(out_dir, "weights.npz"),
        **{n: np.asarray(params[n]) for n in M.PARAM_ORDER},
    )
    with open(os.path.join(out_dir, "config.json"), "w") as f:
        f.write(cfg.to_json())
    C.write_vocab_json(os.path.join(out_dir, "vocab.json"))
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump(log, f, indent=1)
    return {"params": params, "cfg": cfg, "log": log}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/models")
    ap.add_argument(
        "--steps", type=int, default=int(os.environ.get("LAGKV_TRAIN_STEPS", "300"))
    )
    ap.add_argument("--variants", nargs="*", default=list(C.MODEL_VARIANTS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    for variant in args.variants:
        train_variant(
            variant,
            os.path.join(args.out, variant),
            args.steps,
            args.seed,
            resume=args.resume,
        )


if __name__ == "__main__":
    main()

"""Pure-jnp reference oracles for the L1 Pallas kernels.

These implement the paper's equations directly and serve as the correctness
ground truth for

* the Pallas kernels (python/tests/test_kernels.py, hypothesis sweeps), and
* the pure-Rust scorer in rust/src/compress/ (via golden vectors emitted by
  aot.py into artifacts/golden/).
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-6


def _softmax_seq(x):
    m = x.max(axis=1, keepdims=True)
    e = jnp.exp(x - m)
    return e / e.sum(axis=1, keepdims=True)


def lagkv_scores_ref(k_cur, v_cur, k_ref, v_ref):
    """LagKV token scores, Eqs. (5)-(9) of the paper.

    Args:
      k_cur, v_cur: [H, L, D] current partition K/V states.
      k_ref, v_ref: [H, L, D] next ("lag") partition, the reference.
    Returns:
      scores: [H, L] — per-head token importance (higher = keep).

    Per head h and channel d:
      min/max over the *reference's* sequence axis (Eqs. 5-6),
      min-max normalize the current partition (Eq. 7),
      per-token std across channels, softmax over the partition (Eq. 8),
      sum of K-score and V-score (Eq. 9).
    """

    def one(cur, ref):
        mn = ref.min(axis=1, keepdims=True)  # [H, 1, D]
        mx = ref.max(axis=1, keepdims=True)
        norm = (cur - mn) / (mx - mn + EPS)  # [H, L, D]
        std = norm.std(axis=2)  # [H, L] channel-wise std per token
        return _softmax_seq(std)

    return one(k_cur, k_ref) + one(v_cur, v_ref)


def localkv_scores_ref(k_cur, v_cur):
    """LocalKV variant (Appendix A.2, Eqs. 12-13): min/max from the local
    chunk itself instead of the lag reference."""
    return lagkv_scores_ref(k_cur, v_cur, k_cur, v_cur)


def l2norm_scores_ref(k_cur):
    """Recursive L2-norm variant (Appendix A.2, Eq. 14): score = -||K||_2.

    Value states are ignored; low key-norm tokens are *kept* (the negation
    makes higher = keep, matching the top-k convention)."""
    return -jnp.linalg.norm(k_cur, axis=2)  # [H, L]


def decode_attention_ref(q, k, v, length):
    """Single-query attention against a (possibly over-allocated) KV cache.

    Args:
      q: [Hq, D] query for the new token (already RoPE-rotated).
      k, v: [Hkv, T, D] cache (rows >= length are garbage and masked out).
      length: scalar int — number of valid cache rows.
    Returns:
      out: [Hq, D], probs: [Hq, T]
    """
    hq, d = q.shape
    hkv, t, _ = k.shape
    group = hq // hkv
    kq = jnp.repeat(k, group, axis=0)  # [Hq, T, D]
    vq = jnp.repeat(v, group, axis=0)
    logits = jnp.einsum("hd,htd->ht", q, kq) / jnp.sqrt(jnp.float32(d))
    mask = jnp.arange(t)[None, :] < length
    logits = jnp.where(mask, logits, -1e30)
    probs = jnp.exp(logits - logits.max(axis=1, keepdims=True))
    probs = probs * mask
    probs = probs / probs.sum(axis=1, keepdims=True)
    out = jnp.einsum("ht,htd->hd", probs, vq)
    return out, probs


def topk_indices_ref(scores, k):
    """Indices of the k largest scores per head, returned in ascending index
    order (the stable layout used by the cache compactor)."""
    idx = jnp.argsort(-scores, axis=1, stable=True)[:, :k]
    return jnp.sort(idx, axis=1)

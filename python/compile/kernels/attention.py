"""L1 Pallas kernel: single-query (decode-step) attention over a compacted
KV cache with a valid-length mask.

This is the FlashAttention-style hot spot that LagKV is designed to compose
with: the kernel never materializes attention weights for the coordinator —
token importance comes from the LagKV score kernel instead (the paper's
central "attention-free" point).  A separate instrumented path
(`decode_attention_probs`) *does* expose the probability row; it exists only
to feed the H2O baseline and to demonstrate exactly the infrastructure
burden the paper criticizes (§1).

Grid: one step per query head.  Each step stages the head's KV-group cache
tile [T, D] into VMEM and performs an online-softmax accumulation over
sequence tiles of size BLK.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, blk: int):
    """One query head vs its KV group's cache.

    q_ref: [1, D]; k_ref, v_ref: [1, T, D] (the group's cache); len_ref: [1]
    valid-row count; o_ref: [1, D].
    """
    q = q_ref[0]  # [D]
    _, t, d = k_ref.shape
    length = len_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    n_blocks = t // blk

    def body(i, carry):
        m_prev, l_prev, acc = carry
        ix = (0, pl.dslice(i * blk, blk), slice(None))
        k_tile = pl.load(k_ref, ix)  # [BLK, D]
        v_tile = pl.load(v_ref, ix)
        s = (k_tile @ q) * scale  # [BLK]
        idx = i * blk + jnp.arange(blk)
        s = jnp.where(idx < length, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # [BLK]
        l_new = l_prev * alpha + jnp.sum(p)
        acc = acc * alpha + p @ v_tile  # [D]
        return m_new, l_new, acc

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0, :] = acc / jnp.maximum(l, 1e-30)


@functools.partial(jax.jit, static_argnames=("blk",))
def decode_attention(q, k, v, length, blk: int = 64):
    """Online-softmax decode attention.

    Args:
      q: [Hq, D] RoPE-rotated query row.
      k, v: [Hkv, T, D] compacted cache; rows >= `length` are masked.
      length: scalar int32 valid-row count (shared across heads: the cache
        compactor keeps per-head token *identities* distinct but counts
        equal — see rust/src/kvcache/).
      blk: sequence tile size (T must be a multiple).
    Returns:
      [Hq, D] attention output.
    """
    hq, d = q.shape
    hkv, t, _ = k.shape
    group = hq // hkv
    assert t % blk == 0, (t, blk)
    lens = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (hq,))

    q_spec = pl.BlockSpec((1, d), lambda i: (i, 0))
    kv_spec = pl.BlockSpec((1, t, d), lambda i: (i // group, 0, 0))
    len_spec = pl.BlockSpec((1,), lambda i: (i,))

    kernel = functools.partial(_decode_attn_kernel, blk=blk)
    return pl.pallas_call(
        kernel,
        grid=(hq,),
        in_specs=[q_spec, kv_spec, kv_spec, len_spec],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, d), jnp.float32),
        interpret=True,
    )(q, k, v, lens)


@jax.jit
def decode_attention_probs(q, k, v, length):
    """Instrumented (non-Pallas) decode attention that ALSO returns the
    attention probability row, aggregated over each KV group — the extra
    output the H2O baseline requires.  Plain jnp on purpose: this is the
    "incompatible with FlashAttention" path of the paper's argument."""
    hq, d = q.shape
    hkv, t, _ = k.shape
    group = hq // hkv
    kq = jnp.repeat(k, group, axis=0)
    vq = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hd,htd->ht", q, kq) / jnp.sqrt(jnp.float32(d))
    mask = jnp.arange(t)[None, :] < length
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=1) * mask
    p = p / jnp.maximum(p.sum(axis=1, keepdims=True), 1e-30)
    out = jnp.einsum("ht,htd->hd", p, vq)
    probs_kv = p.reshape(hkv, group, t).sum(axis=1)  # [Hkv, T]
    return out, probs_kv

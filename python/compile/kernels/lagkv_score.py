"""L1 Pallas kernel: LagKV token scoring (Eqs. 5-9).

One grid step per KV head.  Each step stages the head's current partition
and its lag reference (four [L, D] tiles, K/V x cur/ref) into VMEM, runs the
min-max / std / softmax reduction chain entirely on-chip, and writes the
[L] score row.

TPU mapping (DESIGN.md §Hardware-Adaptation): the reductions are VPU work —
no MXU involvement — so this kernel never contends with the attention
kernel's systolic-array pipeline.  VMEM footprint per grid step is
4*L*D*4 bytes (~128 KiB at the paper's L=1024, D=64/128 scale), far under
the ~16 MiB budget, leaving headroom for double-buffering the HBM->VMEM
stream.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO ops that the
Rust runtime's CPU client runs bit-identically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-6


def _score_half(cur, lag):
    """Softmax'd channel-std of the lag-normalized tile.  cur/lag: [L, D]."""
    # Eqs. 5-6: per-channel min/max over the reference's sequence axis.
    mn = jnp.min(lag, axis=0, keepdims=True)  # [1, D]
    mx = jnp.max(lag, axis=0, keepdims=True)
    # Eq. 7: min-max normalize the current partition.
    norm = (cur - mn) / (mx - mn + EPS)  # [L, D]
    # Eq. 8: channel-wise std per token, then softmax along the partition.
    mean = jnp.mean(norm, axis=1, keepdims=True)
    std = jnp.sqrt(jnp.mean((norm - mean) ** 2, axis=1))  # [L]
    m = jnp.max(std)
    e = jnp.exp(std - m)
    return e / jnp.sum(e)


def _lagkv_kernel(kc_ref, vc_ref, kl_ref, vl_ref, out_ref):
    """Fused kernel body: score(K) + score(V) in one VMEM residency.

    Block shapes are [1, L, D] (one head per grid step); out is [1, L].
    """
    kc = kc_ref[0]
    vc = vc_ref[0]
    kl = kl_ref[0]
    vl = vl_ref[0]
    # Eq. 9: final token score is the sum of the K-score and the V-score.
    out_ref[0, :] = _score_half(kc, kl) + _score_half(vc, vl)


@jax.jit
def lagkv_scores(k_cur, v_cur, k_ref, v_ref):
    """LagKV scores for a whole partition, all heads.

    Args:
      k_cur, v_cur, k_ref, v_ref: [H, L, D] float32.
    Returns:
      [H, L] float32 scores (higher = keep).
    """
    h, l, d = k_cur.shape
    spec = pl.BlockSpec((1, l, d), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _lagkv_kernel,
        grid=(h,),
        in_specs=[spec, spec, spec, spec],
        out_specs=pl.BlockSpec((1, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, l), jnp.float32),
        interpret=True,
    )(k_cur, v_cur, k_ref, v_ref)


@jax.jit
def localkv_scores(k_cur, v_cur):
    """LocalKV variant: reference is the chunk itself (Eqs. 12-13)."""
    return lagkv_scores(k_cur, v_cur, k_cur, v_cur)


def _l2_kernel(k_ref, out_ref):
    k = k_ref[0]
    out_ref[0, :] = -jnp.sqrt(jnp.sum(k * k, axis=-1))


@jax.jit
def l2norm_scores(k_cur):
    """Recursive L2-norm variant (Eq. 14): score = -||K||_2 per token."""
    h, l, d = k_cur.shape
    return pl.pallas_call(
        _l2_kernel,
        grid=(h,),
        in_specs=[pl.BlockSpec((1, l, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, l), jnp.float32),
        interpret=True,
    )(k_cur)

"""Shared vocabulary and model configuration for the LagKV reproduction.

This module is the single source of truth for the token vocabulary and the
tiny-GQA model architecture.  The Rust coordinator loads the same vocabulary
from ``artifacts/models/<name>/vocab.json`` so that build-time (python) and
serve-time (rust) tokenization agree byte-for-byte.

Vocabulary layout (fixed, deterministic):

    0..6            specials: <pad> <bos> <eos> <sep> <q> <a> <unk>
    7..16           single digits  "0".."9"
    17..116         packed 2-digit "00".."99"
    117..1116       packed 3-digit "000".."999"
    1117..          filler / content words (WORDS below)

Both the "qwen-like" (1 digit per token) and "llama-like" (3 digits per
token) tokenizers share this vocabulary; they differ only in how runs of
digits are segmented (see tokenizer.py).  This mirrors the paper's Fig. 2
observation that Qwen2.5 uses one token per digit while Llama-3.1 packs
three digits per token.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List

# -- specials -----------------------------------------------------------------

PAD, BOS, EOS, SEP, Q, A, UNK = range(7)
SPECIALS = ["<pad>", "<bos>", "<eos>", "<sep>", "<q>", "<a>", "<unk>"]

# -- filler / content words ---------------------------------------------------
# A small closed vocabulary of words used by every workload generator.  The
# first 64 are "filler" words (haystack material), the rest are "content"
# words used as nouns/values in QA-style tasks.  Order is load-bearing: ids
# are assigned by position and the Rust generators index into the same list.

FILLER_WORDS: List[str] = [
    "the", "a", "of", "and", "to", "in", "is", "it", "on", "as", "with",
    "was", "for", "at", "by", "be", "this", "that", "from", "or", "an",
    "are", "not", "we", "his", "but", "they", "she", "her", "you", "all",
    "will", "one", "there", "so", "out", "up", "if", "about", "who", "get",
    "which", "when", "make", "can", "like", "time", "just", "him", "know",
    "take", "people", "into", "year", "your", "good", "some", "could",
    "them", "see", "other", "than", "then", "now",
]

CONTENT_WORDS: List[str] = [
    "apple", "river", "stone", "cloud", "tiger", "maple", "ocean", "candle",
    "silver", "meadow", "falcon", "ember", "harbor", "lantern", "orchid",
    "pebble", "quartz", "raven", "saddle", "thistle", "umbra", "velvet",
    "willow", "zephyr", "anchor", "basil", "cedar", "dahlia", "elm",
    "fern", "ginger", "hazel", "iris", "jasper", "kelp", "lotus",
    "mango", "nutmeg", "olive", "pine", "quince", "rose", "sage",
    "tulip", "violet", "walnut", "yarrow", "zinnia", "blue", "red",
    "green", "gold", "black", "white", "amber", "coral", "crimson",
    "indigo", "ivory", "jade", "onyx", "pearl", "ruby", "teal",
    "alpha", "beta", "gamma", "delta", "omega", "sigma", "kappa", "theta",
    "north", "south", "east", "west", "spring", "summer", "autumn",
    "winter", "copper", "iron", "zinc", "nickel", "cobalt", "helium",
    "neon", "argon", "xenon", "radon", "quark", "boson", "lepton",
    "hadron", "photon", "proton", "magnet", "prism",
]

STRUCT_WORDS: List[str] = [
    # structural words used by task templates (kept separate so templates
    # never collide with haystack filler)
    "pass", "key", "remember", "what", "summary", "value", "color",
    "code", "call", "def", "return", "(", ")", ":", ".", ",",
    "in:", "out:", "doc", "fact", "item", "is",
]

WORDS: List[str] = FILLER_WORDS + CONTENT_WORDS + STRUCT_WORDS

# -- vocabulary ---------------------------------------------------------------

DIGIT1 = [str(d) for d in range(10)]
DIGIT2 = [f"{d:02d}" for d in range(100)]
DIGIT3 = [f"{d:03d}" for d in range(1000)]

DIGIT1_BASE = len(SPECIALS)                     # 7
DIGIT2_BASE = DIGIT1_BASE + len(DIGIT1)         # 17
DIGIT3_BASE = DIGIT2_BASE + len(DIGIT2)         # 117
WORD_BASE = DIGIT3_BASE + len(DIGIT3)           # 1117


def build_vocab() -> List[str]:
    """Full id -> surface-string table."""
    return SPECIALS + DIGIT1 + DIGIT2 + DIGIT3 + WORDS


VOCAB: List[str] = build_vocab()
VOCAB_SIZE: int = len(VOCAB)
TOKEN_TO_ID: Dict[str, int] = {s: i for i, s in enumerate(VOCAB)}
# Duplicate surfaces resolve to the FIRST id ("0" -> digit1, never digit3
# slice): dict construction above keeps the first occurrence only if we
# insert in order and skip existing keys.
TOKEN_TO_ID = {}
for _i, _s in enumerate(VOCAB):
    TOKEN_TO_ID.setdefault(_s, _i)


# -- model configuration ------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of the tiny GQA transformer (shared by both models)."""

    name: str = "tiny-gqa"
    vocab_size: int = VOCAB_SIZE
    d_model: int = 128
    n_layers: int = 4
    n_q_heads: int = 4
    n_kv_heads: int = 2
    d_head: int = 32
    d_ff: int = 256
    max_seq: int = 512
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def group_size(self) -> int:
        return self.n_q_heads // self.n_kv_heads

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)

    @staticmethod
    def from_json(text: str) -> "ModelConfig":
        return ModelConfig(**json.loads(text))


# The two model variants of the paper, distinguished only by tokenizer mode
# (weights are trained separately on the matching token stream).
MODEL_VARIANTS = {
    "llama_like": {"digits_per_token": 3},
    "qwen_like": {"digits_per_token": 1},
}


def write_vocab_json(path: str) -> None:
    """Write the vocab artifact consumed by the Rust tokenizer."""
    payload = {
        "specials": SPECIALS,
        "digit1_base": DIGIT1_BASE,
        "digit2_base": DIGIT2_BASE,
        "digit3_base": DIGIT3_BASE,
        "word_base": WORD_BASE,
        "words": WORDS,
        "vocab_size": VOCAB_SIZE,
        "tokens": VOCAB,
    }
    with open(path, "w") as f:
        json.dump(payload, f)

//! Passkey retrieval (the paper's §3.3 headline experiment) across
//! eviction policies, at one (S, L, r) setting.
//!
//! ```bash
//! cargo run --release --example passkey_retrieval -- --items 10 --lag 64 --ratio 0.25
//! ```

use lagkv::backend::EngineSpec;
use lagkv::config::PolicyKind;
use lagkv::harness::{cfg, EvalOptions};
use lagkv::metrics::Table;
use lagkv::util::cli::Args;
use lagkv::util::rng::Rng;
use lagkv::workloads::passkey::{gen_passkey, PasskeySpec};
use lagkv::workloads::score_item;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let model = args.get_or("model", "llama_like");
    let lag = args.usize_or("lag", 64)?;
    let ratio = args.f64_or("ratio", 0.25)?;
    let items = args.usize_or("items", 10)?;
    let engine = EngineSpec::from_args(&args)?.build(model)?;

    let mut table = Table::new(
        &format!("64-digit passkey retrieval, {model}, S=4, L={lag}, r={ratio}"),
        &["policy", "partial-match", "cache_len", "events"],
    );

    for policy in [
        PolicyKind::None,
        PolicyKind::LagKv,
        PolicyKind::LocalKv,
        PolicyKind::L2Norm,
        PolicyKind::H2O,
        PolicyKind::Streaming,
        PolicyKind::Random,
    ] {
        let comp = cfg(policy, lag, ratio);
        let opts = EvalOptions { n_items: items, ..Default::default() };
        let mut rng = Rng::seed_from(opts.seed);
        let mut total = 0.0;
        let mut cache_len = 0usize;
        let mut events = 0usize;
        for i in 0..items {
            let n_filler =
                if engine.tokenizer.digits_per_token == 1 { 210 } else { 260 };
            let item =
                gen_passkey(&mut rng, &PasskeySpec { n_filler, n_digits: 64, depth: None });
            let out = engine.generate(&item.prompt, &comp, opts.max_new, i as u64)?;
            total += score_item(&item, &out.text);
            cache_len = out.cache_lens.iter().copied().max().unwrap_or(0);
            events += out.compression_events;
        }
        table.row(vec![
            policy.name().to_string(),
            Table::fmt_f(total / items as f64),
            cache_len.to_string(),
            events.to_string(),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

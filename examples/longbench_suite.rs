//! LongBench-like six-family suite (Table 1's columns) at one compression
//! setting vs the uncompressed baseline.
//!
//! ```bash
//! cargo run --release --example longbench_suite -- --items 8 --lag 128 --ratio 0.5
//! ```

use lagkv::backend::EngineSpec;
use lagkv::config::PolicyKind;
use lagkv::harness::{cfg, eval_family, EvalOptions};
use lagkv::metrics::Table;
use lagkv::util::cli::Args;
use lagkv::workloads::longbench;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let model = args.get_or("model", "llama_like");
    let lag = args.usize_or("lag", 128)?;
    let ratio = args.f64_or("ratio", 0.5)?;
    let engine = EngineSpec::from_args(&args)?.build(model)?;
    let opts = EvalOptions { n_items: args.usize_or("items", 8)?, ..Default::default() };

    let mut table = Table::new(
        &format!("LongBench-like suite, {model} (S=4, L={lag})"),
        &["family", "baseline", &format!("lagkv r={ratio}"), "delta"],
    );
    let base_cfg = cfg(PolicyKind::None, lag, 1.0);
    let comp_cfg = cfg(PolicyKind::LagKv, lag, ratio);
    let mut base_avg = 0.0;
    let mut comp_avg = 0.0;
    for fam in longbench::FAMILIES {
        let b = eval_family(&engine, fam, &base_cfg, &opts)?;
        let c = eval_family(&engine, fam, &comp_cfg, &opts)?;
        base_avg += b;
        comp_avg += c;
        table.row(vec![
            longbench::family_label(fam).to_string(),
            Table::fmt_f(b),
            Table::fmt_f(c),
            format!("{:+.2}", c - b),
        ]);
    }
    let n = longbench::FAMILIES.len() as f64;
    table.row(vec![
        "LB Avg.".into(),
        Table::fmt_f(base_avg / n),
        Table::fmt_f(comp_avg / n),
        format!("{:+.2}", (comp_avg - base_avg) / n),
    ]);
    println!("{}", table.render());
    Ok(())
}

//! End-to-end serving driver (DESIGN.md §4 row E2E): boots the full stack —
//! router, per-model coordinator threads with continuous batching, TCP
//! server — fires a mixed batch of concurrent clients at it, and reports
//! latency percentiles + throughput.  This is the proof that all layers
//! compose: rust coordinator -> PJRT runtime -> AOT HLO of the JAX model
//! that calls the Pallas kernel's scoring graph.
//!
//! ```bash
//! cargo run --release --example serve_demo -- --requests 24 --clients 6
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use lagkv::coordinator::Router;
use lagkv::metrics::{Histogram, Table};
use lagkv::server::{Client, Server};
use lagkv::util::cli::Args;
use lagkv::util::json::Json;
use lagkv::util::rng::Rng;
use lagkv::workloads::longbench;
use lagkv::workloads::passkey::{gen_passkey, PasskeySpec};
use lagkv::workloads::score_item;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let spec = lagkv::backend::EngineSpec::from_args(&args)?;
    let port = args.usize_or("port", 7199)? as u16;
    let n_requests = args.usize_or("requests", 24)?;
    let n_clients = args.usize_or("clients", 6)?;

    // Boot the stack.
    let models = vec!["llama_like".to_string(), "qwen_like".to_string()];
    let router = Arc::new(Router::start(spec, &models));
    let server = Arc::new(Server::new(router));
    let stop = Arc::new(AtomicBool::new(false));
    {
        let server = server.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            if let Err(e) = server.serve(port, stop) {
                eprintln!("server: {e:#}");
            }
        });
    }
    std::thread::sleep(std::time::Duration::from_millis(300));

    // Build a mixed workload: passkey + longbench families, two models,
    // compressed and baseline traffic interleaved.
    let mut rng = Rng::seed_from(5);
    let mut requests: Vec<(String, String, String)> = Vec::new(); // (model, json, answer)
    for i in 0..n_requests {
        let model = if i % 2 == 0 { "llama_like" } else { "qwen_like" };
        let (item, policy) = if i % 3 == 0 {
            let nf = if model == "qwen_like" { 180 } else { 230 };
            (
                gen_passkey(&mut rng, &PasskeySpec { n_filler: nf, n_digits: 32, depth: None }),
                "lagkv",
            )
        } else {
            let fam = longbench::FAMILIES[i % longbench::FAMILIES.len()];
            (longbench::generate(fam, &mut rng, 180), if i % 2 == 0 { "lagkv" } else { "none" })
        };
        let req = lagkv::util::json::obj(vec![
            ("id", lagkv::util::json::n(i as f64)),
            ("model", lagkv::util::json::s(model)),
            ("prompt", lagkv::util::json::s(item.prompt.clone())),
            ("policy", lagkv::util::json::s(policy)),
            ("lag", lagkv::util::json::n(32.0)),
            ("ratio", lagkv::util::json::n(0.5)),
            ("max_new", lagkv::util::json::n(40.0)),
        ]);
        requests.push((model.to_string(), req.to_string(), item.answer.clone()));
        // keep the item for scoring
        requests.last_mut().unwrap().2 = item.answer.clone();
        // stash family in the answer tuple via item (scored below against passkey family only)
        let _ = &item;
    }

    // Fan out over client threads.
    let started = Instant::now();
    let chunk = requests.len().div_ceil(n_clients);
    let mut handles = Vec::new();
    for (ci, batch) in requests.chunks(chunk).enumerate() {
        let batch: Vec<_> = batch.to_vec();
        handles.push(std::thread::spawn(move || -> anyhow::Result<(Histogram, u64, usize)> {
            let mut client = Client::connect(port)?;
            let mut hist = Histogram::new();
            let mut tokens = 0u64;
            let mut errors = 0usize;
            for (_, line, _) in &batch {
                let t0 = Instant::now();
                let resp = client.call(line)?;
                hist.record(t0.elapsed());
                if resp.opt("error").map(|e| *e != Json::Null).unwrap_or(false) {
                    errors += 1;
                } else {
                    tokens += resp.get("new_tokens")?.as_usize()? as u64;
                }
            }
            let _ = ci;
            Ok((hist, tokens, errors))
        }));
    }

    let mut hist = Histogram::new();
    let mut total_tokens = 0u64;
    let mut errors = 0usize;
    for h in handles {
        let (h2, t, e) = h.join().expect("client thread")?;
        hist.merge(&h2);
        total_tokens += t;
        errors += e;
    }
    let wall = started.elapsed().as_secs_f64();

    let mut t = Table::new(
        "serve_demo: end-to-end serving (continuous batching, 2 models)",
        &["metric", "value"],
    );
    t.row(vec!["requests".into(), n_requests.to_string()]);
    t.row(vec!["clients".into(), n_clients.to_string()]);
    t.row(vec!["errors".into(), errors.to_string()]);
    t.row(vec!["wall s".into(), format!("{wall:.2}")]);
    t.row(vec!["requests/s".into(), format!("{:.2}", n_requests as f64 / wall)]);
    t.row(vec!["gen tokens/s".into(), format!("{:.1}", total_tokens as f64 / wall)]);
    t.row(vec!["latency p50 ms".into(), format!("{:.1}", hist.p50_ms())]);
    t.row(vec!["latency p95 ms".into(), format!("{:.1}", hist.p95_ms())]);
    t.row(vec!["latency p99 ms".into(), format!("{:.1}", hist.p99_ms())]);
    println!("{}", t.render());

    stop.store(true, Ordering::Relaxed);
    Ok(())
}

//! End-to-end serving driver (DESIGN.md §4 row E2E): boots the full stack —
//! router, per-model coordinator threads with continuous batching, TCP
//! server — fires a mixed batch of concurrent clients at it (one-shot and
//! streaming traffic interleaved, all through the typed `lagkv::client`
//! SDK), reports latency percentiles, throughput, and streaming TTFT, runs
//! a two-turn session to show the compressed cache being reused across
//! turns, then walks the ops control plane: `stats` for the wire-level
//! pool/prefix/coordinator gauges and `drain` for the typed admission
//! shutdown.
//!
//! Memory budgets: `--pool-mb N` caps each model's KV block pool (typed
//! `pool-exhausted` rejections + spill-first shedding under pressure) and
//! `--session-mb N` caps the session store's resident bytes.
//! `--prefix-cache` shares identical prompt prefixes across sequences CoW
//! (per-model hit/miss/reuse gauges are printed at the end).
//! `--store-dir DIR` opts into tiered storage: cold frozen blocks spill to
//! disk under pool pressure and detached sessions / prefix snapshots are
//! WAL-journaled so they survive a restart of the demo; `--store-max-mb N`
//! caps that directory (coldest spilled inventory evicted LRU over the
//! cap).  `--quant int8[:LAYERS]` freezes blocks through the int8 codec —
//! the per-model gauge line grows a `quantized` segment showing exact
//! encoded residency.
//!
//! ```bash
//! cargo run --release --example serve_demo -- --requests 24 --clients 6
//! cargo run --release --example serve_demo -- --pool-mb 4 --session-mb 1
//! cargo run --release --example serve_demo -- --prefix-cache
//! cargo run --release --example serve_demo -- --store-dir /tmp/lagkv-demo --store-max-mb 64
//! cargo run --release --example serve_demo -- --quant int8
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use lagkv::client::{Client, StreamItem};
use lagkv::coordinator::{Event, GenerateParams, Router, RouterConfig};
use lagkv::metrics::{Histogram, PoolGauges, Table};
use lagkv::server::Server;
use lagkv::util::cli::Args;
use lagkv::util::rng::Rng;
use lagkv::workloads::longbench;
use lagkv::workloads::passkey::{gen_passkey, PasskeySpec};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let spec = lagkv::backend::EngineSpec::from_args(&args)?;
    let n_requests = args.usize_or("requests", 24)?;
    let n_clients = args.usize_or("clients", 6)?;

    // Boot the stack on an ephemeral port.
    let models = vec!["llama_like".to_string(), "qwen_like".to_string()];
    let mut router_cfg = RouterConfig::default();
    match args.usize_or("pool-mb", 0)? {
        0 => {} // absent or explicit 0: uncapped, like --session-mb 0
        mb => router_cfg.pool_max_bytes = Some(mb * 1024 * 1024),
    }
    router_cfg.sessions.max_bytes = args.usize_or("session-mb", 0)? * 1024 * 1024;
    if args.has("prefix-cache") {
        router_cfg.prefix_cache = Some(lagkv::kvpool::PrefixConfig::default());
    }
    router_cfg.store_dir = args.get("store-dir").map(std::path::PathBuf::from);
    match args.usize_or("store-max-mb", 0)? {
        0 => {} // absent or explicit 0: uncapped, like --pool-mb 0
        mb => router_cfg.store_max_bytes = Some(mb * 1024 * 1024),
    }
    if let Some(q) = args.get("quant") {
        router_cfg.quant = lagkv::quant::QuantSpec::parse(q)?;
    }
    let router = Arc::new(Router::start_with(spec, &models, router_cfg));
    let server = Arc::new(Server::new(router));
    let stop = Arc::new(AtomicBool::new(false));
    let (listener, port) = Server::bind(args.usize_or("port", 0)? as u16)?;
    {
        let server = server.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            if let Err(e) = server.serve_listener(listener, stop) {
                eprintln!("server: {e:#}");
            }
        });
    }

    // Build a mixed workload: passkey + longbench families, two models,
    // compressed and baseline traffic, every third request streaming.
    let mut rng = Rng::seed_from(5);
    let mut requests: Vec<(u64, GenerateParams, bool)> = Vec::new();
    for i in 0..n_requests {
        let model = if i % 2 == 0 { "llama_like" } else { "qwen_like" };
        let item = if i % 3 == 0 {
            let nf = if model == "qwen_like" { 180 } else { 230 };
            gen_passkey(&mut rng, &PasskeySpec { n_filler: nf, n_digits: 32, depth: None })
        } else {
            let fam = longbench::FAMILIES[i % longbench::FAMILIES.len()];
            longbench::generate(fam, &mut rng, 180)
        };
        let policy = if i % 2 == 0 { "lagkv" } else { "none" };
        let params = GenerateParams::new(item.prompt)
            .model(model)
            .policy(lagkv::config::PolicyKind::parse(policy)?)
            .lag(32)
            .ratio(0.5)
            .max_new(40);
        requests.push((i as u64, params, i % 3 == 0));
    }

    // Fan out over client threads, all traffic through the typed SDK.
    let started = Instant::now();
    let chunk = requests.len().div_ceil(n_clients);
    let mut handles = Vec::new();
    for batch in requests.chunks(chunk) {
        let batch: Vec<_> = batch.to_vec();
        handles.push(std::thread::spawn(
            move || -> anyhow::Result<(Histogram, Histogram, u64, usize)> {
                let mut client = Client::connect(port)?;
                let mut lat = Histogram::new();
                let mut ttft = Histogram::new();
                let mut tokens = 0u64;
                let mut errors = 0usize;
                for (id, params, streaming) in batch {
                    let t0 = Instant::now();
                    if streaming {
                        let mut stream = client.generate_stream(id, params)?;
                        let mut saw_token = false;
                        while let Some(item) = stream.next()? {
                            match item {
                                StreamItem::Event(Event::Token { .. }) => {
                                    if !saw_token {
                                        saw_token = true;
                                        ttft.record(t0.elapsed());
                                    }
                                    tokens += 1;
                                }
                                StreamItem::Event(Event::Error { .. }) => errors += 1,
                                _ => {}
                            }
                        }
                        lat.record(t0.elapsed());
                    } else {
                        let resp = client.generate(Some(id), params)?;
                        lat.record(t0.elapsed());
                        if resp.error.is_some() {
                            errors += 1;
                        } else {
                            tokens += resp.tokens.len() as u64;
                        }
                    }
                }
                Ok((lat, ttft, tokens, errors))
            },
        ));
    }

    let mut lat = Histogram::new();
    let mut ttft = Histogram::new();
    let mut total_tokens = 0u64;
    let mut errors = 0usize;
    for h in handles {
        let (h_lat, h_ttft, t, e) = h.join().expect("client thread")?;
        lat.merge(&h_lat);
        ttft.merge(&h_ttft);
        total_tokens += t;
        errors += e;
    }
    let wall = started.elapsed().as_secs_f64();

    let mut t = Table::new(
        "serve_demo: end-to-end serving (continuous batching, streaming, 2 models)",
        &["metric", "value"],
    );
    t.row(vec!["requests".into(), n_requests.to_string()]);
    t.row(vec!["clients".into(), n_clients.to_string()]);
    t.row(vec!["errors".into(), errors.to_string()]);
    t.row(vec!["wall s".into(), format!("{wall:.2}")]);
    t.row(vec!["requests/s".into(), format!("{:.2}", n_requests as f64 / wall)]);
    t.row(vec!["gen tokens/s".into(), format!("{:.1}", total_tokens as f64 / wall)]);
    t.row(vec!["latency p50 ms".into(), format!("{:.1}", lat.p50_ms())]);
    t.row(vec!["latency p95 ms".into(), format!("{:.1}", lat.p95_ms())]);
    t.row(vec!["latency p99 ms".into(), format!("{:.1}", lat.p99_ms())]);
    t.row(vec!["stream TTFT p50 ms".into(), format!("{:.1}", ttft.p50_ms())]);
    println!("{}", t.render());

    // Two-turn session: the second turn prefills only its own text and the
    // cache lengths continue the compressed trajectory from turn 1.
    let mut client = Client::connect(port)?;
    let mut rng = Rng::seed_from(9);
    let turn1 = gen_passkey(&mut rng, &PasskeySpec { n_filler: 150, n_digits: 16, depth: None });
    let t1 = client.generate(
        Some(9001),
        GenerateParams::new(turn1.prompt).lag(16).ratio(0.25).max_new(12).session("demo-chat"),
    )?;
    let t2 = client.generate(
        Some(9002),
        GenerateParams::new("<q> the pass key <a>")
            .lag(16)
            .ratio(0.25)
            .max_new(12)
            .session("demo-chat"),
    )?;
    println!("\nsession demo (id \"demo-chat\"):");
    println!("  turn 1: prompt_tokens={} cache_lens={:?}", t1.prompt_tokens, t1.cache_lens);
    println!(
        "  turn 2: prompt_tokens={} reused_tokens={} cache_lens={:?}",
        t2.prompt_tokens, t2.reused_tokens, t2.cache_lens,
    );

    // Ops control plane: the same pool/prefix gauges the in-proc accessors
    // expose, but read over the wire — the session above shows up in the
    // per-model session gauges, and the stored entry is listable.
    let stats = client.stats()?;
    println!();
    for m in &stats.models {
        let mut gauges = PoolGauges::from(&m.pool);
        if let Some(p) = &m.prefix {
            gauges = gauges.with_prefix(p);
        }
        println!("{}: {}", m.model, gauges.render());
        println!(
            "  coord: completed {} queued {}/{} | sessions {} ({:.1} KiB)",
            m.coord.completed,
            m.coord.queued,
            m.queue_capacity,
            m.sessions.entries,
            m.sessions.bytes as f64 / 1024.0,
        );
    }
    let listed = client.sessions(None)?;
    for m in &listed.models {
        for ss in &m.sessions {
            println!(
                "session {}/{}: turns={} rows={} bytes={}",
                m.model, ss.id, ss.turns, ss.rows, ss.bytes
            );
        }
    }

    // Drain: admission closes with a typed rejection; in-flight work (none
    // left here) finishes before the operator stops the accept loop.
    let drained = client.drain()?;
    let rejected = client.generate(Some(9003), GenerateParams::new("post-drain probe"))?;
    println!(
        "\ndrain: draining={} in_flight={} | post-drain submit -> {}",
        drained.draining,
        drained.in_flight,
        rejected.error.map(|e| e.code()).unwrap_or("accepted?!"),
    );

    stop.store(true, Ordering::Relaxed);
    Ok(())
}

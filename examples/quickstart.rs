//! Quickstart: load a model, generate with LagKV compression, inspect the
//! cache.  Runs hermetically on the CPU reference backend:
//!
//! ```bash
//! cargo run --release --example quickstart
//! # or, with the PJRT artifact path:
//! make artifacts && cargo run --release --features xla --example quickstart -- --backend xla
//! ```

use lagkv::backend::EngineSpec;
use lagkv::config::PolicyKind;
use lagkv::coordinator::GenerateParams;
use lagkv::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let engine = EngineSpec::from_args(&args)?.build("llama_like")?;
    println!(
        "loaded {} on {}: {} layers, {} kv heads, context {}",
        engine.variant,
        engine.backend().platform(),
        engine.dims.n_layers,
        engine.dims.n_kv_heads,
        engine.tmax
    );

    // A tiny single-doc QA prompt in the model's synthetic language.
    let prompt = "the river was by the stone and all of it now \
                  fact the falcon is crimson . \
                  one year out of the time like some other there \
                  <q> the falcon <a>";

    for (label, params) in [
        (
            "baseline (no compression)",
            GenerateParams::new(prompt).policy(PolicyKind::None).max_new(8),
        ),
        (
            "lagkv 4x (S=4, L=16, r=0.25)",
            GenerateParams::new(prompt)
                .policy(PolicyKind::LagKv)
                .sink(4)
                .lag(16)
                .ratio(0.25)
                .max_new(8),
        ),
    ] {
        let out = engine.run(&params)?;
        println!("\n[{label}]");
        println!("  answer: {:?}", out.text);
        println!(
            "  prompt_tokens={} cache_lens={:?} compression_events={}",
            out.prompt_tokens, out.cache_lens, out.compression_events
        );
        println!(
            "  prefill {:.1} ms, decode {:.1} ms",
            out.prefill_us as f64 / 1000.0,
            out.decode_us as f64 / 1000.0
        );
    }
    Ok(())
}

//! Hermetic server smoke check (CI job `server-smoke`): boots the TCP
//! server on an ephemeral port over the CPU reference backend and drives
//! it entirely through the typed `lagkv::client` SDK — zero hand-rolled
//! JSON.  Covered end-to-end:
//!
//! * the ops control plane: `info` (engine facts) before any traffic,
//!   `stats` (pool/prefix/coordinator gauges) after it, `sessions`
//!   list/delete, and `drain` → typed `draining` rejection → clean
//!   shutdown;
//! * one streaming request (typed events) and one cancel mid-decode;
//! * memory-pressure admission on a tiny byte-budgeted pool: LRU session
//!   shedding, the typed `pool-exhausted` rejection, recovery;
//! * the radix prefix cache: CoW prefix reuse across clients
//!   (`reused_tokens > 0`), prefix-snapshot shedding under pressure;
//! * observability: the `trace` op returns a complete span timeline
//!   (queued → admitted → prefill segments → first token → compression →
//!   done) with monotone timestamps, nonzero TTFT, zero dropped events,
//!   and the `--trace-dir` NDJSON file carries the same spans;
//! * quantized mode (`--quant int8`): frozen blocks land encoded — the
//!   `quant_bytes`/`quant_blocks` gauges report exact encoded residency
//!   over the wire — and a session resume round-trips through them.
//!
//! Exits non-zero on any protocol violation.
//!
//! ```bash
//! cargo run --release --example server_smoke
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use lagkv::backend::EngineSpec;
use lagkv::client::{Client, StreamItem};
use lagkv::config::PolicyKind;
use lagkv::coordinator::{Event, GenerateParams, Router, RouterConfig, SessionConfig};
use lagkv::engine::Engine;
use lagkv::kvpool::row_bytes;
use lagkv::server::Server;
use lagkv::util::rng::Rng;
use lagkv::workloads::passkey::{gen_passkey, PasskeySpec};

/// A prompt whose greedy chain runs long enough that a cancel sent after
/// the first token always lands mid-decode (the toy LM head ends most
/// chains early with EOS, so scan for a long one).
fn long_prompt(engine: &Engine) -> anyhow::Result<String> {
    let none = GenerateParams::new("x").policy(PolicyKind::None).compression();
    for seed in 0..400u64 {
        let mut rng = Rng::seed_from(seed);
        let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 20, n_digits: 8, depth: None });
        let out = engine.generate(&item.prompt, &none, 600, 0)?;
        if out.tokens.len() >= 64 {
            return Ok(item.prompt);
        }
    }
    anyhow::bail!("no prompt with a >=64-token greedy chain in 400 candidates")
}

fn main() -> anyhow::Result<()> {
    // The chain scan runs on a throwaway engine; the server gets its own.
    let probe = Engine::cpu_ref("llama_like")?;
    let prompt = long_prompt(&probe)?;

    let models = vec!["llama_like".to_string()];
    let trace_root =
        std::env::temp_dir().join(format!("lagkv-smoke-trace-{}", std::process::id()));
    let cfg = RouterConfig { trace_dir: Some(trace_root.clone()), ..Default::default() };
    let router = Arc::new(Router::start_with(EngineSpec::cpu(), &models, cfg));
    let server = Arc::new(Server::new(router));
    let stop = Arc::new(AtomicBool::new(false));
    let (listener, port) = Server::bind(0)?;
    let serve_thread = {
        let server = server.clone();
        let stop = stop.clone();
        std::thread::spawn(move || server.serve_listener(listener, stop))
    };

    // 0. Control plane before any traffic: `info` reports the engine facts
    //    a client sizes itself from.
    let mut client = Client::connect(port)?;
    let info = client.info()?;
    assert_eq!(info.version, 1, "this build speaks wire protocol v1");
    assert_eq!(info.models.len(), 1, "one model served: {info:?}");
    let mi = &info.models[0];
    assert_eq!(mi.model, "llama_like");
    assert!(!mi.prefill_buckets.is_empty(), "prefill buckets must be exported");
    assert!(mi.decode_buckets.contains(&1), "b=1 decode is the session path");
    assert_eq!(mi.max_prompt_tokens, *mi.prefill_buckets.iter().max().unwrap());
    assert!(mi.pool_budget_bytes.is_none(), "unbudgeted deployment");
    assert!(info.policies.contains(&"lagkv".to_string()));
    assert!(info.policies.contains(&"none".to_string()));
    println!(
        "info ok: prefill {:?}, decode {:?}, {} policies",
        mi.prefill_buckets,
        mi.decode_buckets,
        info.policies.len()
    );

    // 1. One streaming request: started -> token+ -> done, typed events.
    let params = GenerateParams::new("the pass key is 12345678 . remember it <q> pass key <a>")
        .lag(16)
        .ratio(0.5)
        .max_new(12);
    let mut stream = client.generate_stream(1, params)?;
    let mut events = Vec::new();
    while let Some(item) = stream.next()? {
        if let StreamItem::Event(ev) = item {
            events.push(ev);
        }
    }
    assert!(events.len() >= 3, "expected started/token/done, got {} events", events.len());
    assert!(matches!(events[0], Event::Started { .. }), "first event: {:?}", events[0]);
    let n_tokens = events.iter().filter(|e| matches!(e, Event::Token { .. })).count();
    assert!(n_tokens >= 1, "stream produced no tokens");
    match events.last().unwrap() {
        Event::Done { usage, .. } => {
            assert_eq!(usage.new_tokens, n_tokens, "done must count the tokens")
        }
        other => panic!("stream must end with done, got {other:?}"),
    }
    println!("streaming ok: {n_tokens} tokens");

    // 2. Cancel an unknown id: acked, not found.
    assert!(!client.cancel(777)?, "unknown id must not be found");

    // 3. A long streaming request cancelled mid-decode: read one token,
    //    cancel through the stream handle, then the stream must terminate
    //    with code "cancelled" before the generation budget is spent.
    let params = GenerateParams::new(prompt).lag(16).ratio(0.5).max_new(600);
    let mut stream = client.generate_stream(2, params)?;
    let mut seen_tokens = 0usize;
    let mut cancelled = false;
    let mut sent_cancel = false;
    while let Some(item) = stream.next()? {
        match item {
            StreamItem::Event(Event::Token { .. }) => {
                seen_tokens += 1;
                if !sent_cancel {
                    sent_cancel = true;
                    stream.cancel()?;
                }
            }
            StreamItem::CancelAck(ack) => {
                assert!(ack.found, "live id must be found");
            }
            StreamItem::Event(Event::Error { error, .. }) => {
                assert_eq!(error.code(), "cancelled", "terminal error: {error}");
                cancelled = true;
            }
            StreamItem::Event(Event::Done { .. }) => {
                panic!("request completed before the cancel landed")
            }
            _ => {}
        }
    }
    assert!(cancelled);
    assert!(seen_tokens < 600, "cancel must abort mid-decode ({seen_tokens} tokens seen)");
    println!("cancellation ok: aborted after {seen_tokens} tokens");

    // 3b. `stats` after traffic: the coordinator counters and the exact
    //     pool ledger are visible over the wire.
    let stats = client.stats()?;
    assert!(!stats.draining);
    assert_eq!(stats.models.len(), 1);
    let ms = &stats.models[0];
    assert!(ms.coord.completed >= 1, "completed counter: {:?}", ms.coord);
    assert_eq!(ms.coord.cancelled, 1, "one cancel: {:?}", ms.coord);
    assert_eq!(ms.coord.queued, 0, "queue drained: {:?}", ms.coord);
    assert!(ms.pool.high_water_bytes > 0, "traffic must move the pool ledger");
    assert!(ms.prefix.is_none(), "no prefix cache configured");
    println!("stats ok: completed {} cancelled {}", ms.coord.completed, ms.coord.cancelled);

    // 3c. Observability end-to-end: stream a request long enough that the
    //     compression driver fires, then read its span through the `trace`
    //     op — the full queued → admitted → prefill → first-token →
    //     compression → done timeline with monotone timestamps, nonzero
    //     TTFT, and an exactly-zero drop counter.
    use lagkv::telemetry::SpanEventKind;
    let traced_prompt = "the of and to in is it on as with ".repeat(16);
    let params = GenerateParams::new(traced_prompt).lag(8).ratio(0.5).max_new(8);
    let mut stream = client.generate_stream(3, params)?;
    let mut compression_events = 0usize;
    while let Some(item) = stream.next()? {
        if let StreamItem::Event(Event::Compression { .. }) = item {
            compression_events += 1;
        }
    }
    assert!(compression_events >= 1, "the traced request must compress");
    // the span publishes when the slot is reaped, just after the terminal
    // event reaches us — poll briefly
    let mut span = None;
    for _ in 0..100 {
        let tr = client.trace()?;
        assert_eq!(tr.models.len(), 1);
        assert_eq!(tr.models[0].dropped_events, 0, "no span may be dropped: {tr:?}");
        if let Some(sp) = tr.models[0].spans.iter().find(|sp| sp.id == 3) {
            span = Some(sp.clone());
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let span = span.expect("the traced request's span must be served by `trace`");
    let kinds: Vec<SpanEventKind> = span.events.iter().map(|e| e.kind).collect();
    assert_eq!(kinds.first(), Some(&SpanEventKind::Queued), "span opens queued: {kinds:?}");
    assert_eq!(kinds.last(), Some(&SpanEventKind::Done), "span closes done: {kinds:?}");
    assert!(kinds.contains(&SpanEventKind::Admitted), "missing admitted: {kinds:?}");
    assert!(
        kinds.contains(&SpanEventKind::PrefillSegment),
        "missing prefill segments: {kinds:?}"
    );
    assert!(kinds.contains(&SpanEventKind::Compression), "missing compression: {kinds:?}");
    assert!(kinds.contains(&SpanEventKind::FirstToken), "missing first token: {kinds:?}");
    for w in span.events.windows(2) {
        assert!(w[0].t_us <= w[1].t_us, "span timestamps must be monotone: {span:?}");
    }
    let queued_t = span.first(SpanEventKind::Queued).unwrap().t_us;
    let first_tok_t = span.first(SpanEventKind::FirstToken).unwrap().t_us;
    assert!(first_tok_t > queued_t, "TTFT must be nonzero: {span:?}");
    let tr = client.trace()?;
    let ttft = tr.models[0]
        .histograms
        .iter()
        .find(|h| h.metric.name() == "ttft")
        .expect("a completed request must feed the ttft histogram");
    assert!(ttft.count >= 1 && ttft.p50_us > 0, "ttft summary: {ttft:?}");
    // the same spans stream to the --trace-dir NDJSON file (the trace
    // snapshot above forced a drain, which also writes the file)
    let trace_file = trace_root.join("llama_like.trace.ndjson");
    let ndjson = std::fs::read_to_string(&trace_file)?;
    assert!(
        ndjson.lines().any(|l| l.contains("\"id\":3")),
        "trace file must carry span 3: {trace_file:?}"
    );
    println!(
        "trace ok: span 3 with {} event(s), ttft p50 {}us, {} NDJSON line(s)",
        span.events.len(),
        ttft.p50_us,
        ndjson.lines().count()
    );

    // and the `stats` op folds the same histogram summaries in
    let stats_after = client.stats()?;
    let ms_hists = &stats_after.models[0].histograms;
    assert!(
        ms_hists.iter().any(|h| h.metric.name() == "ttft"),
        "stats must fold histogram summaries in: {ms_hists:?}"
    );

    // 4. Clean shutdown.  The forwarder thread deregisters its request
    //    right after writing the terminal line; give it a moment.
    drop(client);
    stop.store(true, Ordering::Relaxed);
    serve_thread.join().expect("server thread")?;
    for _ in 0..100 {
        if server.live_requests() == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(server.live_requests(), 0, "no request may survive shutdown");

    // 5. Memory-pressure admission on a tiny byte-budgeted pool: a
    //    session fills it, a moderate request recovers by shedding that
    //    session, and an oversized request is a typed `pool-exhausted`
    //    rejection on the wire.
    let dims = &probe.dims;
    let row = row_bytes(dims.n_layers, dims.n_kv_heads, dims.d_head);
    let budget = 200 * row; // ~200 cache rows total
    let tiny_cfg = RouterConfig {
        queue_depth: 8,
        sessions: SessionConfig::default(),
        pool_max_bytes: Some(budget),
        prefix_cache: None,
        ..Default::default()
    };
    let router2 = Arc::new(Router::start_with(EngineSpec::cpu(), &models, tiny_cfg));
    let stats2 = router2.stats("llama_like").expect("model stats");
    let server2 = Arc::new(Server::new(router2.clone()));
    let stop2 = Arc::new(AtomicBool::new(false));
    let (listener2, port2) = Server::bind(0)?;
    let serve2 = {
        let server2 = server2.clone();
        let stop2 = stop2.clone();
        std::thread::spawn(move || server2.serve_listener(listener2, stop2))
    };
    let mut client2 = Client::connect(port2)?;
    let mut rng = Rng::seed_from(41);
    let small_prompt = |rng: &mut Rng| {
        gen_passkey(rng, &PasskeySpec { n_filler: 60, n_digits: 8, depth: None }).prompt
    };
    let small = |rng: &mut Rng, max_new: usize| {
        GenerateParams::new(small_prompt(rng)).lag(16).ratio(0.5).max_new(max_new)
    };

    // A: a session turn that fits and stays resident in the store.
    let a = client2.generate(Some(20), small(&mut rng, 8).session("mem-1"))?;
    assert!(a.error.is_none(), "session turn must fit: {a:?}");
    let pool2 = router2.pool("llama_like").expect("pool");
    assert!(pool2.resident_bytes() > 0, "the detached session must stay resident");

    // A': the stored session is listable over the wire.  (The store entry
    // lands right after the terminal event is written, so poll briefly.)
    let mut listed = client2.sessions(Some("llama_like"))?;
    for _ in 0..100 {
        if !listed.models[0].sessions.is_empty() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        listed = client2.sessions(Some("llama_like"))?;
    }
    assert_eq!(listed.models.len(), 1);
    let entry = &listed.models[0].sessions;
    assert_eq!(entry.len(), 1, "one stored session: {listed:?}");
    assert_eq!(entry[0].id, "mem-1");
    assert_eq!(entry[0].turns, 1);
    assert!(entry[0].bytes > 0 && entry[0].rows > 0);

    // B: a request whose worst case exceeds the whole budget is a typed
    //    rejection — and it must NOT shed the innocent stored session on
    //    the way out (shedding cannot make an impossible request fit).
    let d_resp = client2.generate(Some(21), small(&mut rng, 600))?;
    let code = d_resp.error.as_ref().map(|e| e.code());
    assert_eq!(code, Some("pool-exhausted"), "oversized request: {d_resp:?}");
    assert_eq!(stats2.pool_rejected.load(Ordering::Relaxed), 1);
    assert_eq!(
        stats2.sessions_shed.load(Ordering::Relaxed),
        0,
        "an impossible request must not destroy stored sessions"
    );
    assert!(pool2.resident_bytes() > 0, "the session survives the rejection");

    // C: a fresh request whose estimate only fits if the LRU session is
    //    shed — recovery under pressure.
    let b = client2.generate(Some(22), small(&mut rng, 100))?;
    assert!(b.error.is_none(), "request must recover by shedding: {b:?}");
    assert!(
        stats2.sessions_shed.load(Ordering::Relaxed) >= 1,
        "the stored session must have been shed to admit the new work"
    );

    // D: after rejection and shedding the pool still serves right-sized
    //    work, and the shed session resumes as a fresh conversation.
    let c = client2.generate(Some(23), small(&mut rng, 8).session("mem-1"))?;
    assert!(c.error.is_none(), "pool must recover: {c:?}");
    assert_eq!(c.reused_tokens, 0, "the shed session must restart from scratch");

    // D': the control plane deletes the re-stored session outright (poll:
    // the entry lands just after the turn's terminal event).
    let mut deleted = client2.delete_session(None, "mem-1")?;
    for _ in 0..100 {
        if deleted == 1 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
        deleted = client2.delete_session(None, "mem-1")?;
    }
    assert_eq!(deleted, 1, "one entry deleted");
    assert!(client2.sessions(None)?.models[0].sessions.is_empty());
    println!(
        "pool pressure ok: shed {} session(s), {} typed rejection(s)",
        stats2.sessions_shed.load(Ordering::Relaxed),
        stats2.pool_rejected.load(Ordering::Relaxed),
    );

    drop(client2);
    stop2.store(true, Ordering::Relaxed);
    serve2.join().expect("budgeted server thread")?;

    // 6. Radix prefix cache over a budgeted pool: two clients share a long
    //    system prompt; the second must hit the prefix cache (CoW attach,
    //    `reused_tokens > 0` on the wire), then pool pressure sheds prefix
    //    snapshots (the cheapest tier) and the cache recovers.  The prefix
    //    gauges are asserted over the wire through the `stats` op, and the
    //    run ends with the drain handshake: `drain` -> typed `draining`
    //    rejection -> clean shutdown.
    let prefix_budget = 1200 * row;
    let prefix_cfg = RouterConfig {
        queue_depth: 8,
        sessions: SessionConfig::default(),
        pool_max_bytes: Some(prefix_budget),
        prefix_cache: Some(lagkv::kvpool::PrefixConfig { stride: 24, ..Default::default() }),
        ..Default::default()
    };
    let router3 = Arc::new(Router::start_with(EngineSpec::cpu(), &models, prefix_cfg));
    let server3 = Arc::new(Server::new(router3));
    let stop3 = Arc::new(AtomicBool::new(false));
    let (listener3, port3) = Server::bind(0)?;
    let serve3 = {
        let server3 = server3.clone();
        let stop3 = stop3.clone();
        std::thread::spawn(move || server3.serve_listener(listener3, stop3))
    };
    let mut rng3 = Rng::seed_from(77);
    let sys = gen_passkey(&mut rng3, &PasskeySpec { n_filler: 120, n_digits: 16, depth: None })
        .prompt;
    let turn = |q: &str, max_new: usize| {
        GenerateParams::new(format!("{sys} {q}")).lag(16).ratio(0.5).max_new(max_new)
    };

    // client A warms the tree with the shared prefix
    let mut client_a = Client::connect(port3)?;
    let a1 = client_a.generate(Some(30), turn("<q> the pass key <a>", 8))?;
    assert!(a1.error.is_none(), "warming request failed: {a1:?}");
    assert_eq!(a1.reused_tokens, 0, "a cold tree cannot hit");

    // client B shares the system prompt and must attach the prefix CoW
    let mut client_b = Client::connect(port3)?;
    let b1 = client_b.generate(Some(31), turn("<q> remember the words <a>", 8))?;
    assert!(b1.error.is_none(), "shared-prefix request failed: {b1:?}");
    assert!(b1.reused_tokens > 0, "second client must hit the prefix cache: {b1:?}");
    let wire = client_b.stats()?;
    let prefix_gauges = wire.models[0].prefix.expect("prefix gauges on the wire");
    assert!(prefix_gauges.hits >= 1, "hit gauge must record the attach: {prefix_gauges:?}");
    assert!(prefix_gauges.entries >= 1);
    println!("prefix cache ok: second client reused {} prompt tokens", b1.reused_tokens);

    // pool pressure: a huge generation budget forces prefix-snapshot
    // shedding (tier 1) before admission — and the request still runs
    let big = client_b.generate(Some(32), turn("<q> the pass key <a>", 999))?;
    assert!(big.error.is_none(), "shedding must admit it: {big:?}");
    let shed = client_b.stats()?.models[0].prefix.expect("gauges").shed;
    assert!(shed >= 1, "pressure must shed prefix snapshots first");

    // recovery: the tree repopulates from fresh traffic
    let a2 = client_a.generate(Some(33), turn("<q> the pass key <a>", 8))?;
    assert!(a2.error.is_none(), "post-shed request failed: {a2:?}");
    let after = client_b.stats()?.models[0].prefix.expect("gauges");
    assert!(after.entries >= 1, "tree must repopulate after shedding");
    println!(
        "prefix pressure ok: shed {} snapshot(s), {} entries resident",
        after.shed, after.entries,
    );

    // 7. Drain handshake: admission closes with a typed rejection while
    //    the connection stays serviceable; undrain reopens it (the
    //    rollback half of a rolling restart), then the shutdown is clean.
    let drained = client_b.drain()?;
    assert!(drained.draining);
    let rejected = client_b.generate(Some(34), turn("<q> the pass key <a>", 4))?;
    assert_eq!(
        rejected.error.as_ref().map(|e| e.code()),
        Some("draining"),
        "post-drain submit must be the typed rejection: {rejected:?}"
    );
    assert!(client_b.stats()?.draining, "stats must report the drain");
    println!("drain ok: typed rejection after admission closed");

    let reopened = client_b.undrain()?;
    assert!(!reopened.draining, "undrain must report admission reopened");
    assert!(!client_b.stats()?.draining, "stats must report the undrain");
    let accepted = client_b.generate(Some(35), turn("<q> the pass key <a>", 4))?;
    assert!(accepted.error.is_none(), "post-undrain submit must be accepted: {accepted:?}");
    println!("undrain ok: admission reopened and a request ran");

    drop(client_a);
    drop(client_b);
    stop3.store(true, Ordering::Relaxed);
    serve3.join().expect("prefix server thread")?;

    // 8. Tiered storage restart: populate a detached session and a shared
    //    prefix on a --store-dir deployment, checkpoint over the wire,
    //    kill the server, and restart on the same directory.  The replayed
    //    inventory must serve the session resume and the prefix hit
    //    without re-prefilling (reused_tokens > 0 for both), and the
    //    restored blocks must sit on the disk tier until first touch
    //    (spilled gauges over the wire).  Hermetic: the store lives in a
    //    tempdir removed at the end.
    let store_root =
        std::env::temp_dir().join(format!("lagkv-smoke-store-{}", std::process::id()));
    let store_cfg = || RouterConfig {
        queue_depth: 8,
        sessions: SessionConfig::default(),
        pool_max_bytes: None,
        prefix_cache: Some(lagkv::kvpool::PrefixConfig { stride: 24, ..Default::default() }),
        store_dir: Some(store_root.clone()),
        ..Default::default()
    };
    let mut rng4 = Rng::seed_from(91);
    let sys4 = gen_passkey(&mut rng4, &PasskeySpec { n_filler: 120, n_digits: 16, depth: None })
        .prompt;
    let turn4 = |q: &str| GenerateParams::new(format!("{sys4} {q}")).lag(16).ratio(0.5).max_new(8);

    // first boot: one session turn + one prefix-warming request
    let router4 = Arc::new(Router::start_with(EngineSpec::cpu(), &models, store_cfg()));
    let server4 = Arc::new(Server::new(router4));
    let stop4 = Arc::new(AtomicBool::new(false));
    let (listener4, port4) = Server::bind(0)?;
    let serve4 = {
        let server4 = server4.clone();
        let stop4 = stop4.clone();
        std::thread::spawn(move || server4.serve_listener(listener4, stop4))
    };
    let mut client4 = Client::connect(port4)?;
    let warm = client4.generate(Some(40), turn4("<q> the pass key <a>").session("disk-1"))?;
    assert!(warm.error.is_none(), "store-backed turn failed: {warm:?}");
    // the store entry lands after the terminal event; poll until listed
    for _ in 0..100 {
        if !client4.sessions(None)?.models[0].sessions.is_empty() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(client4.sessions(None)?.models[0].sessions.len(), 1);
    let cp = client4.checkpoint()?;
    assert_eq!(cp.models.len(), 1, "one store to flush: {cp:?}");
    let summary = cp.models[0].result.as_ref().expect("checkpoint must succeed");
    assert!(summary.sessions >= 1, "the session must be journaled: {summary:?}");
    assert!(summary.blocks > 0, "frozen blocks must be persisted: {summary:?}");
    println!(
        "checkpoint ok: {} session(s), {} prefix(es), {} block(s)",
        summary.sessions, summary.prefixes, summary.blocks
    );
    drop(client4);
    stop4.store(true, Ordering::Relaxed);
    serve4.join().expect("store server thread")?;

    // second boot, same directory: the journal replays the inventory
    let router5 = Arc::new(Router::start_with(EngineSpec::cpu(), &models, store_cfg()));
    let server5 = Arc::new(Server::new(router5));
    let stop5 = Arc::new(AtomicBool::new(false));
    let (listener5, port5) = Server::bind(0)?;
    let serve5 = {
        let server5 = server5.clone();
        let stop5 = stop5.clone();
        std::thread::spawn(move || server5.serve_listener(listener5, stop5))
    };
    let mut client5 = Client::connect(port5)?;
    let listed = client5.sessions(None)?;
    assert_eq!(listed.models[0].sessions.len(), 1, "replayed session: {listed:?}");
    assert_eq!(listed.models[0].sessions[0].id, "disk-1");
    assert_eq!(listed.models[0].sessions[0].turns, 1, "turn count survives the restart");
    let tiers = client5.stats()?;
    let pool5 = &tiers.models[0].pool;
    assert!(
        pool5.spilled_blocks > 0,
        "restored blocks must start on the disk tier: {pool5:?}"
    );
    assert_eq!(pool5.resident_blocks, 0, "nothing faults in before first touch: {pool5:?}");

    // the detached session resumes without re-prefilling its history
    let resumed = client5.generate(Some(41), turn4("<q> again <a>").session("disk-1"))?;
    assert!(resumed.error.is_none(), "post-restart resume failed: {resumed:?}");
    assert!(
        resumed.reused_tokens > 0,
        "the resumed session must reuse its replayed cache: {resumed:?}"
    );

    // the journaled prefix snapshot serves a cold client CoW
    let hit = client5.generate(Some(42), turn4("<q> remember the words <a>"))?;
    assert!(hit.error.is_none(), "post-restart prefix request failed: {hit:?}");
    assert!(
        hit.reused_tokens > 0,
        "the replayed prefix snapshot must hit without re-prefilling: {hit:?}"
    );
    println!(
        "restart ok: session resumed {} tokens, prefix reused {} tokens, \
         {} block(s) replayed from disk",
        resumed.reused_tokens, hit.reused_tokens, pool5.spilled_blocks,
    );

    drop(client5);
    stop5.store(true, Ordering::Relaxed);
    serve5.join().expect("restarted store server thread")?;

    // 9. Quantized mode (`--quant int8`): every frozen block lands
    //    encoded, the stats op reports *exact* encoded residency
    //    (quant_bytes is a closed-form multiple of quant_blocks), and a
    //    session resume round-trips through encoded blocks.
    let quant_cfg = RouterConfig {
        queue_depth: 8,
        sessions: SessionConfig::default(),
        quant: lagkv::quant::QuantSpec::parse("int8").expect("int8 spec parses"),
        ..Default::default()
    };
    let router6 = Arc::new(Router::start_with(EngineSpec::cpu(), &models, quant_cfg));
    let server6 = Arc::new(Server::new(router6));
    let stop6 = Arc::new(AtomicBool::new(false));
    let (listener6, port6) = Server::bind(0)?;
    let serve6 = {
        let server6 = server6.clone();
        let stop6 = stop6.clone();
        std::thread::spawn(move || server6.serve_listener(listener6, stop6))
    };
    let mut client6 = Client::connect(port6)?;
    let q1 = client6.generate(Some(60), turn4("<q> the pass key <a>").session("q-1"))?;
    assert!(q1.error.is_none(), "quantized turn failed: {q1:?}");
    let qstats = client6.stats()?;
    let qpool = &qstats.models[0].pool;
    assert!(qpool.quant_blocks > 0, "compression must freeze encoded blocks: {qpool:?}");
    let enc_bpb = lagkv::quant::CodecKind::Int8Sym.encoded_block_bytes(
        lagkv::kvpool::BlockPool::DEFAULT_ROWS_PER_BLOCK,
        dims.d_head,
    );
    assert_eq!(
        qpool.quant_bytes,
        qpool.quant_blocks * enc_bpb,
        "encoded residency must be exact over the wire: {qpool:?}"
    );
    assert_eq!(qpool.resident_blocks, 0, "no plain block under --quant int8: {qpool:?}");
    let q2 = client6.generate(Some(61), turn4("<q> again <a>").session("q-1"))?;
    assert!(q2.error.is_none(), "quantized resume failed: {q2:?}");
    assert!(
        q2.reused_tokens > 0,
        "the resumed session must reuse its encoded cache: {q2:?}"
    );
    println!(
        "quantized ok: {} encoded block(s) = {} bytes exact, resume reused {} tokens",
        qpool.quant_blocks, qpool.quant_bytes, q2.reused_tokens,
    );
    drop(client6);
    stop6.store(true, Ordering::Relaxed);
    serve6.join().expect("quantized server thread")?;

    std::fs::remove_dir_all(&store_root).ok();
    std::fs::remove_dir_all(&trace_root).ok();
    println!("SMOKE OK");
    Ok(())
}

//! Hermetic server smoke check (CI job `server-smoke`): boots the TCP
//! server on an ephemeral port over the CPU reference backend, runs one
//! streaming request and one cancelled request, and asserts a clean
//! shutdown.  Exits non-zero on any protocol violation.
//!
//! ```bash
//! cargo run --release --example server_smoke
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use lagkv::backend::EngineSpec;
use lagkv::config::PolicyKind;
use lagkv::coordinator::{GenerateParams, Router, RouterConfig};
use lagkv::engine::Engine;
use lagkv::server::{Client, Server};
use lagkv::util::json::Json;
use lagkv::util::rng::Rng;
use lagkv::workloads::passkey::{gen_passkey, PasskeySpec};

fn kind(ev: &Json) -> String {
    ev.opt("event").and_then(|e| e.as_str().ok()).unwrap_or("").to_string()
}

/// A prompt whose greedy chain runs long enough that a cancel sent after
/// the first token always lands mid-decode (the toy LM head ends most
/// chains early with EOS, so scan for a long one).
fn long_prompt(engine: &Engine) -> anyhow::Result<String> {
    let none = GenerateParams::new("x").policy(PolicyKind::None).compression();
    for seed in 0..400u64 {
        let mut rng = Rng::seed_from(seed);
        let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 20, n_digits: 8, depth: None });
        let out = engine.generate(&item.prompt, &none, 600, 0)?;
        if out.tokens.len() >= 64 {
            return Ok(item.prompt);
        }
    }
    anyhow::bail!("no prompt with a >=64-token greedy chain in 400 candidates")
}

fn main() -> anyhow::Result<()> {
    // The chain scan runs on a throwaway engine; the server gets its own.
    let probe = Engine::cpu_ref("llama_like")?;
    let prompt = long_prompt(&probe)?;

    let models = vec!["llama_like".to_string()];
    let cfg = RouterConfig::default();
    let router = Arc::new(Router::start_with(EngineSpec::cpu(), &models, cfg));
    let server = Arc::new(Server::new(router));
    let stop = Arc::new(AtomicBool::new(false));
    let (listener, port) = Server::bind(0)?;
    let serve_thread = {
        let server = server.clone();
        let stop = stop.clone();
        std::thread::spawn(move || server.serve_listener(listener, stop))
    };

    // 1. One streaming request: started -> token+ -> done, deltas nonempty.
    let mut client = Client::connect(port)?;
    let line = GenerateParams::new("the pass key is 12345678 . remember it <q> pass key <a>")
        .lag(16)
        .ratio(0.5)
        .max_new(12)
        .request_line(Some(1), true);
    let events = client.stream(&line)?;
    assert!(events.len() >= 3, "expected started/token/done, got {} events", events.len());
    assert_eq!(kind(&events[0]), "started", "first event: {:?}", events[0]);
    assert_eq!(kind(events.last().unwrap()), "done");
    let n_tokens = events.iter().filter(|e| kind(e) == "token").count();
    assert!(n_tokens >= 1, "stream produced no tokens");
    let done = events.last().unwrap();
    assert_eq!(done.get("new_tokens")?.as_usize()?, n_tokens, "done must count the tokens");
    println!("streaming ok: {n_tokens} tokens");

    // 2. Cancel an unknown id: acked, not found.
    client.send_line(r#"{"cancel": 777}"#)?;
    let ack = client.read_json()?;
    assert_eq!(kind(&ack), "cancel_ack");
    assert!(!ack.get("found")?.as_bool()?, "unknown id must not be found");

    // 3. A long streaming request cancelled mid-decode: read one token,
    //    send {"cancel"}, then the stream must terminate with code
    //    "cancelled" before the generation budget is spent.
    let line = GenerateParams::new(prompt)
        .lag(16)
        .ratio(0.5)
        .max_new(600)
        .request_line(Some(2), true);
    client.send_line(&line)?;
    let mut seen_tokens = 0usize;
    let mut cancelled = false;
    let mut sent_cancel = false;
    loop {
        let ev = client.read_json()?;
        match kind(&ev).as_str() {
            "token" => {
                seen_tokens += 1;
                if !sent_cancel {
                    sent_cancel = true;
                    client.send_line(r#"{"cancel": 2}"#)?;
                }
            }
            "cancel_ack" => {
                assert!(ev.get("found")?.as_bool()?, "live id must be found");
            }
            "error" => {
                let code = ev.get("error")?.get("code")?.as_str()?.to_string();
                assert_eq!(code, "cancelled", "terminal error: {ev:?}");
                cancelled = true;
                break;
            }
            "done" => panic!("request completed before the cancel landed"),
            _ => {}
        }
    }
    assert!(cancelled);
    assert!(seen_tokens < 600, "cancel must abort mid-decode ({seen_tokens} tokens seen)");
    println!("cancellation ok: aborted after {seen_tokens} tokens");

    // 4. Clean shutdown.  The forwarder thread deregisters its request
    //    right after writing the terminal line; give it a moment.
    drop(client);
    stop.store(true, Ordering::Relaxed);
    serve_thread.join().expect("server thread")?;
    for _ in 0..100 {
        if server.live_requests() == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(server.live_requests(), 0, "no request may survive shutdown");
    println!("SMOKE OK");
    Ok(())
}

//! Hermetic server smoke check (CI job `server-smoke`): boots the TCP
//! server on an ephemeral port over the CPU reference backend, runs one
//! streaming request and one cancelled request, asserts a clean shutdown,
//! then reboots with a tiny byte-budgeted KV pool and asserts the
//! memory-pressure admission path end-to-end: LRU session shedding under
//! pressure, the typed `pool-exhausted` wire rejection, and recovery
//! afterwards.  A final reboot with `--prefix-cache` semantics drives the
//! shared-system-prompt scenario: two clients whose prompts share a long
//! prefix, the second attaching the radix prefix cache CoW
//! (`reused_tokens > 0` on the wire), then prefix-snapshot shedding under
//! pool pressure and recovery.  Exits non-zero on any protocol violation.
//!
//! ```bash
//! cargo run --release --example server_smoke
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use lagkv::backend::EngineSpec;
use lagkv::config::PolicyKind;
use lagkv::coordinator::{GenerateParams, Router, RouterConfig, SessionConfig};
use lagkv::engine::Engine;
use lagkv::kvpool::row_bytes;
use lagkv::server::{Client, Server};
use lagkv::util::json::Json;
use lagkv::util::rng::Rng;
use lagkv::workloads::passkey::{gen_passkey, PasskeySpec};

fn kind(ev: &Json) -> String {
    ev.opt("event").and_then(|e| e.as_str().ok()).unwrap_or("").to_string()
}

/// A prompt whose greedy chain runs long enough that a cancel sent after
/// the first token always lands mid-decode (the toy LM head ends most
/// chains early with EOS, so scan for a long one).
fn long_prompt(engine: &Engine) -> anyhow::Result<String> {
    let none = GenerateParams::new("x").policy(PolicyKind::None).compression();
    for seed in 0..400u64 {
        let mut rng = Rng::seed_from(seed);
        let item = gen_passkey(&mut rng, &PasskeySpec { n_filler: 20, n_digits: 8, depth: None });
        let out = engine.generate(&item.prompt, &none, 600, 0)?;
        if out.tokens.len() >= 64 {
            return Ok(item.prompt);
        }
    }
    anyhow::bail!("no prompt with a >=64-token greedy chain in 400 candidates")
}

fn main() -> anyhow::Result<()> {
    // The chain scan runs on a throwaway engine; the server gets its own.
    let probe = Engine::cpu_ref("llama_like")?;
    let prompt = long_prompt(&probe)?;

    let models = vec!["llama_like".to_string()];
    let cfg = RouterConfig::default();
    let router = Arc::new(Router::start_with(EngineSpec::cpu(), &models, cfg));
    let server = Arc::new(Server::new(router));
    let stop = Arc::new(AtomicBool::new(false));
    let (listener, port) = Server::bind(0)?;
    let serve_thread = {
        let server = server.clone();
        let stop = stop.clone();
        std::thread::spawn(move || server.serve_listener(listener, stop))
    };

    // 1. One streaming request: started -> token+ -> done, deltas nonempty.
    let mut client = Client::connect(port)?;
    let line = GenerateParams::new("the pass key is 12345678 . remember it <q> pass key <a>")
        .lag(16)
        .ratio(0.5)
        .max_new(12)
        .request_line(Some(1), true);
    let events = client.stream(&line)?;
    assert!(events.len() >= 3, "expected started/token/done, got {} events", events.len());
    assert_eq!(kind(&events[0]), "started", "first event: {:?}", events[0]);
    assert_eq!(kind(events.last().unwrap()), "done");
    let n_tokens = events.iter().filter(|e| kind(e) == "token").count();
    assert!(n_tokens >= 1, "stream produced no tokens");
    let done = events.last().unwrap();
    assert_eq!(done.get("new_tokens")?.as_usize()?, n_tokens, "done must count the tokens");
    println!("streaming ok: {n_tokens} tokens");

    // 2. Cancel an unknown id: acked, not found.
    client.send_line(r#"{"cancel": 777}"#)?;
    let ack = client.read_json()?;
    assert_eq!(kind(&ack), "cancel_ack");
    assert!(!ack.get("found")?.as_bool()?, "unknown id must not be found");

    // 3. A long streaming request cancelled mid-decode: read one token,
    //    send {"cancel"}, then the stream must terminate with code
    //    "cancelled" before the generation budget is spent.
    let line = GenerateParams::new(prompt)
        .lag(16)
        .ratio(0.5)
        .max_new(600)
        .request_line(Some(2), true);
    client.send_line(&line)?;
    let mut seen_tokens = 0usize;
    let mut cancelled = false;
    let mut sent_cancel = false;
    loop {
        let ev = client.read_json()?;
        match kind(&ev).as_str() {
            "token" => {
                seen_tokens += 1;
                if !sent_cancel {
                    sent_cancel = true;
                    client.send_line(r#"{"cancel": 2}"#)?;
                }
            }
            "cancel_ack" => {
                assert!(ev.get("found")?.as_bool()?, "live id must be found");
            }
            "error" => {
                let code = ev.get("error")?.get("code")?.as_str()?.to_string();
                assert_eq!(code, "cancelled", "terminal error: {ev:?}");
                cancelled = true;
                break;
            }
            "done" => panic!("request completed before the cancel landed"),
            _ => {}
        }
    }
    assert!(cancelled);
    assert!(seen_tokens < 600, "cancel must abort mid-decode ({seen_tokens} tokens seen)");
    println!("cancellation ok: aborted after {seen_tokens} tokens");

    // 4. Clean shutdown.  The forwarder thread deregisters its request
    //    right after writing the terminal line; give it a moment.
    drop(client);
    stop.store(true, Ordering::Relaxed);
    serve_thread.join().expect("server thread")?;
    for _ in 0..100 {
        if server.live_requests() == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(server.live_requests(), 0, "no request may survive shutdown");

    // 5. Memory-pressure admission on a tiny byte-budgeted pool: a
    //    session fills it, a moderate request recovers by shedding that
    //    session, and an oversized request is a typed `pool-exhausted`
    //    rejection on the wire.
    let dims = &probe.dims;
    let row = row_bytes(dims.n_layers, dims.n_kv_heads, dims.d_head);
    let budget = 200 * row; // ~200 cache rows total
    let tiny_cfg = RouterConfig {
        queue_depth: 8,
        sessions: SessionConfig::default(),
        pool_max_bytes: Some(budget),
        prefix_cache: None,
    };
    let router2 = Arc::new(Router::start_with(EngineSpec::cpu(), &models, tiny_cfg));
    let stats2 = router2.stats("llama_like").expect("model stats");
    let server2 = Arc::new(Server::new(router2.clone()));
    let stop2 = Arc::new(AtomicBool::new(false));
    let (listener2, port2) = Server::bind(0)?;
    let serve2 = {
        let server2 = server2.clone();
        let stop2 = stop2.clone();
        std::thread::spawn(move || server2.serve_listener(listener2, stop2))
    };
    let mut client2 = Client::connect(port2)?;
    let mut rng = Rng::seed_from(41);
    let small_prompt = |rng: &mut Rng| {
        gen_passkey(rng, &PasskeySpec { n_filler: 60, n_digits: 8, depth: None }).prompt
    };

    // A: a session turn that fits and stays resident in the store.
    let a = client2.call(
        &GenerateParams::new(small_prompt(&mut rng))
            .lag(16)
            .ratio(0.5)
            .max_new(8)
            .session("mem-1")
            .request_line(Some(20), false),
    )?;
    assert_eq!(*a.get("error")?, Json::Null, "session turn must fit: {a:?}");
    let pool2 = router2.pool("llama_like").expect("pool");
    assert!(pool2.resident_bytes() > 0, "the detached session must stay resident");

    // B: a request whose worst case exceeds the whole budget is a typed
    //    rejection — and it must NOT shed the innocent stored session on
    //    the way out (shedding cannot make an impossible request fit).
    let d_resp = client2.call(
        &GenerateParams::new(small_prompt(&mut rng))
            .lag(16)
            .ratio(0.5)
            .max_new(600)
            .request_line(Some(21), false),
    )?;
    let code = d_resp.get("error")?.get("code")?.as_str()?.to_string();
    assert_eq!(code, "pool-exhausted", "oversized request: {d_resp:?}");
    assert_eq!(stats2.pool_rejected.load(Ordering::Relaxed), 1);
    assert_eq!(
        stats2.sessions_shed.load(Ordering::Relaxed),
        0,
        "an impossible request must not destroy stored sessions"
    );
    assert!(pool2.resident_bytes() > 0, "the session survives the rejection");

    // C: a fresh request whose estimate only fits if the LRU session is
    //    shed — recovery under pressure.
    let b = client2.call(
        &GenerateParams::new(small_prompt(&mut rng))
            .lag(16)
            .ratio(0.5)
            .max_new(100)
            .request_line(Some(22), false),
    )?;
    assert_eq!(*b.get("error")?, Json::Null, "request must recover by shedding: {b:?}");
    assert!(
        stats2.sessions_shed.load(Ordering::Relaxed) >= 1,
        "the stored session must have been shed to admit the new work"
    );

    // D: after rejection and shedding the pool still serves right-sized
    //    work, and the shed session resumes as a fresh conversation.
    let c = client2.call(
        &GenerateParams::new(small_prompt(&mut rng))
            .lag(16)
            .ratio(0.5)
            .max_new(8)
            .session("mem-1")
            .request_line(Some(23), false),
    )?;
    assert_eq!(*c.get("error")?, Json::Null, "pool must recover: {c:?}");
    assert_eq!(
        c.get("reused_tokens")?.as_usize()?,
        0,
        "the shed session must restart from scratch"
    );
    println!(
        "pool pressure ok: shed {} session(s), {} typed rejection(s)",
        stats2.sessions_shed.load(Ordering::Relaxed),
        stats2.pool_rejected.load(Ordering::Relaxed),
    );

    drop(client2);
    stop2.store(true, Ordering::Relaxed);
    serve2.join().expect("budgeted server thread")?;

    // 6. Radix prefix cache over a budgeted pool: two clients share a long
    //    system prompt; the second must hit the prefix cache (CoW attach,
    //    `reused_tokens > 0` on the wire), then pool pressure sheds prefix
    //    snapshots (the cheapest tier) and the cache recovers.
    let prefix_budget = 1200 * row;
    let prefix_cfg = RouterConfig {
        queue_depth: 8,
        sessions: SessionConfig::default(),
        pool_max_bytes: Some(prefix_budget),
        prefix_cache: Some(lagkv::kvpool::PrefixConfig { stride: 24, ..Default::default() }),
    };
    let router3 = Arc::new(Router::start_with(EngineSpec::cpu(), &models, prefix_cfg));
    let prefix3 = router3.prefix_cache("llama_like").expect("prefix cache");
    let server3 = Arc::new(Server::new(router3));
    let stop3 = Arc::new(AtomicBool::new(false));
    let (listener3, port3) = Server::bind(0)?;
    let serve3 = {
        let server3 = server3.clone();
        let stop3 = stop3.clone();
        std::thread::spawn(move || server3.serve_listener(listener3, stop3))
    };
    let mut rng3 = Rng::seed_from(77);
    let sys = gen_passkey(&mut rng3, &PasskeySpec { n_filler: 120, n_digits: 16, depth: None })
        .prompt;
    let turn = |q: &str, id: u64, max_new: usize| {
        GenerateParams::new(format!("{sys} {q}"))
            .lag(16)
            .ratio(0.5)
            .max_new(max_new)
            .request_line(Some(id), false)
    };

    // client A warms the tree with the shared prefix
    let mut client_a = Client::connect(port3)?;
    let a1 = client_a.call(&turn("<q> the pass key <a>", 30, 8))?;
    assert_eq!(*a1.get("error")?, Json::Null, "warming request failed: {a1:?}");
    assert_eq!(a1.get("reused_tokens")?.as_usize()?, 0, "a cold tree cannot hit");

    // client B shares the system prompt and must attach the prefix CoW
    let mut client_b = Client::connect(port3)?;
    let b1 = client_b.call(&turn("<q> remember the words <a>", 31, 8))?;
    assert_eq!(*b1.get("error")?, Json::Null, "shared-prefix request failed: {b1:?}");
    let reused = b1.get("reused_tokens")?.as_usize()?;
    assert!(reused > 0, "second client must hit the prefix cache: {b1:?}");
    assert!(prefix3.stats().hits >= 1, "hit gauge must record the attach");
    println!("prefix cache ok: second client reused {reused} prompt tokens");

    // pool pressure: a huge generation budget forces prefix-snapshot
    // shedding (tier 1) before admission — and the request still runs
    let big = client_b.call(&turn("<q> the pass key <a>", 32, 999))?;
    assert_eq!(*big.get("error")?, Json::Null, "shedding must admit it: {big:?}");
    assert!(prefix3.stats().shed >= 1, "pressure must shed prefix snapshots first");

    // recovery: the tree repopulates from fresh traffic
    let a2 = client_a.call(&turn("<q> the pass key <a>", 33, 8))?;
    assert_eq!(*a2.get("error")?, Json::Null, "post-shed request failed: {a2:?}");
    assert!(prefix3.stats().entries >= 1, "tree must repopulate after shedding");
    println!(
        "prefix pressure ok: shed {} snapshot(s), {} entries resident",
        prefix3.stats().shed,
        prefix3.stats().entries,
    );

    drop(client_a);
    drop(client_b);
    stop3.store(true, Ordering::Relaxed);
    serve3.join().expect("prefix server thread")?;
    println!("SMOKE OK");
    Ok(())
}
